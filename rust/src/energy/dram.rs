//! External DRAM energy: the paper's Table IV model — DDR3 at 70 pJ/bit.

/// Table IV's assumption: "DDR3 DRAM energy consumption 70 pJ/bit".
pub const DRAM_PJ_PER_BIT: f64 = 70.0;

/// Energy (mJ) to move `bytes` across the DRAM interface.
pub fn dram_energy_mj(bytes: u64) -> f64 {
    bytes as f64 * 8.0 * DRAM_PJ_PER_BIT * 1e-12 * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_rows() {
        // Original HD: 4656 MB/s -> 2607 mJ/s. Proposed: 585 -> 327.6.
        assert!((dram_energy_mj(4_656_000_000) - 2607.4).abs() < 1.0);
        assert!((dram_energy_mj(585_000_000) - 327.6).abs() < 0.5);
        // 416x416 rows: 903 -> 506, 137 -> 77.
        assert!((dram_energy_mj(903_000_000) - 505.7).abs() < 1.0);
        assert!((dram_energy_mj(137_000_000) - 76.7).abs() < 0.5);
    }

    #[test]
    fn savings_factor() {
        let orig = dram_energy_mj(4_656_000_000);
        let prop = dram_energy_mj(585_000_000);
        assert!((orig / prop - 7.96).abs() < 0.05); // the paper's 7.9x
    }
}
