//! Minimal dynamic error type — the crate's only error currency.
//!
//! The repo builds fully offline with **zero** external dependencies (see
//! the ROADMAP lockfile item: the dependency-free graph is what lets a
//! valid `Cargo.lock` exist without a registry round-trip). This module
//! supplies the small slice of `anyhow`'s ergonomics the crate actually
//! uses: a string-backed [`Error`] that any `std::error::Error` converts
//! into, the [`err!`]/[`bail!`]/[`ensure!`] constructor macros, and a
//! [`Context`] extension for annotating failures.
//!
//! [`err!`]: crate::err
//! [`bail!`]: crate::bail
//! [`ensure!`]: crate::ensure

use std::fmt;

/// A boxed, message-carrying error. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// conversion below cannot collide with the reflexive `From<Error>`.
pub struct Error(String);

impl Error {
    /// An error from a displayable message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `main() -> Result<_, Error>` prints the Debug form on exit;
        // show the message, not a struct dump.
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// Context annotation for fallible values, mirroring the `anyhow` trait
/// of the same name: `ctx` is prepended to the underlying message.
pub trait Context<T> {
    /// Annotate the error with a fixed message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Annotate the error with a lazily-built message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! err {
    ($fmt:literal $($arg:tt)*) => { $crate::error::Error::msg(format!($fmt $($arg)*)) };
    ($e:expr) => { $crate::error::Error::msg($e.to_string()) };
}

/// Return early with an [`Error`] built as by [`err!`].
///
/// [`err!`]: crate::err
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::err!($($t)*)) };
}

/// Return early with an [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        crate::bail!("broke at {}", 7)
    }

    #[test]
    fn macros_build_messages() {
        assert_eq!(fails().unwrap_err().to_string(), "broke at 7");
        let e = crate::err!("x = {}", 1);
        assert_eq!(format!("{e}"), "x = 1");
        assert_eq!(format!("{e:?}"), "x = 1");
        // Single-expression form accepts any displayable value.
        let s = String::from("plain");
        assert_eq!(crate::err!(s).to_string(), "plain");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: u32) -> Result<u32> {
            crate::ensure!(v < 10, "v {v} out of range");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "v 12 out of range");
    }

    #[test]
    fn std_errors_convert() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn context_annotates_results_and_options() {
        let r: std::result::Result<(), String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }
}
