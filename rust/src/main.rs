//! CLI entrypoint — see `rcnet-dla --help`.

fn main() -> anyhow::Result<()> {
    rcnet_dla::cli_main()
}
