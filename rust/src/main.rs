//! CLI entrypoint — see `rcnet-dla --help`.

fn main() -> rcnet_dla::Result<()> {
    rcnet_dla::cli_main()
}
