//! The one Chrome trace-event serialization path.
//!
//! Two subsystems render Chrome trace documents (`chrome://tracing`,
//! Perfetto): the single-frame schedule trace
//! ([`ExecutionTrace::to_chrome_json`](crate::trace::ExecutionTrace::to_chrome_json))
//! and the fleet telemetry export
//! ([`TelemetryReport::to_chrome_json`](crate::serve::TelemetryReport::to_chrome_json)).
//! They used to build the same `"M"`/`"X"`/`"i"`/`"C"` event objects and
//! the same document envelope independently; this module is the single
//! construction path both now share, so the event shapes — and the one
//! string-escaping/serialization path under them
//! ([`crate::util::json::Json`]) — can never drift apart.
//!
//! Byte stability: [`Json`] objects are sorted maps, so an event built
//! here serializes identically to one built field-by-field at the call
//! site — the refactor is pinned byte-identical to the pre-unification
//! writers by the trace and telemetry round-trip tests.

use crate::util::json::Json;

/// An `"M"` thread-name metadata event: names track `tid` (pid is always
/// 0 — one process, tracks are engines or chips).
pub fn thread_meta(tid: usize, label: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", Json::Str(label.into()));
    let mut e = Json::obj();
    e.set("ph", Json::Str("M".into()))
        .set("pid", Json::Num(0.0))
        .set("tid", Json::Num(tid as f64))
        .set("name", Json::Str("thread_name".into()))
        .set("args", args);
    e
}

/// An `"X"` complete event: a span of `dur_us` microseconds starting at
/// `ts_us` on track `tid`, carrying `args`.
pub fn span(tid: usize, name: String, ts_us: f64, dur_us: f64, args: Json) -> Json {
    let mut e = Json::obj();
    e.set("ph", Json::Str("X".into()))
        .set("pid", Json::Num(0.0))
        .set("tid", Json::Num(tid as f64))
        .set("name", Json::Str(name))
        .set("ts", Json::Num(ts_us))
        .set("dur", Json::Num(dur_us))
        .set("args", args);
    e
}

/// An `"i"` instant event (global scope) at `ts_us` on track `tid`.
pub fn instant(tid: usize, name: &str, ts_us: f64, args: Json) -> Json {
    let mut e = Json::obj();
    e.set("ph", Json::Str("i".into()))
        .set("s", Json::Str("g".into()))
        .set("pid", Json::Num(0.0))
        .set("tid", Json::Num(tid as f64))
        .set("name", Json::Str(name.into()))
        .set("ts", Json::Num(ts_us))
        .set("args", args);
    e
}

/// A `"C"` counter event at `ts_us` on track `tid`; each key of `args`
/// renders as one counter series.
pub fn counter(tid: usize, name: &str, ts_us: f64, args: Json) -> Json {
    let mut e = Json::obj();
    e.set("ph", Json::Str("C".into()))
        .set("pid", Json::Num(0.0))
        .set("tid", Json::Num(tid as f64))
        .set("name", Json::Str(name.into()))
        .set("ts", Json::Num(ts_us))
        .set("args", args);
    e
}

/// The document envelope: `displayTimeUnit: "ms"`, the caller's
/// `otherData` header and the event list. Callers may `set` further
/// top-level keys (the telemetry export adds its windowed series,
/// incidents and metrics) — [`Json`] objects are sorted, so extension
/// never perturbs the shared keys.
pub fn document(other_data: Json, events: Vec<Json>) -> Json {
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::Str("ms".into()))
        .set("otherData", other_data)
        .set("traceEvents", Json::Arr(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The event shapes, pinned byte-for-byte: these strings are exactly
    /// what the pre-unification writers emitted.
    #[test]
    fn event_shapes_are_pinned() {
        assert_eq!(
            thread_meta(2, "chip1").to_string(),
            r#"{"args":{"name":"chip1"},"name":"thread_name","ph":"M","pid":0,"tid":2}"#
        );
        let mut args = Json::obj();
        args.set("seq", Json::Num(4.0));
        assert_eq!(
            span(1, "s0#4".into(), 100.0, 50.0, args).to_string(),
            r#"{"args":{"seq":4},"dur":50,"name":"s0#4","ph":"X","pid":0,"tid":1,"ts":100}"#
        );
        assert_eq!(
            instant(0, "arrival", 7.0, Json::obj()).to_string(),
            r#"{"args":{},"name":"arrival","ph":"i","pid":0,"s":"g","tid":0,"ts":7}"#
        );
        assert_eq!(
            counter(0, "bus_bytes", 0.0, Json::obj()).to_string(),
            r#"{"args":{},"name":"bus_bytes","ph":"C","pid":0,"tid":0,"ts":0}"#
        );
    }

    #[test]
    fn document_envelope_is_extensible() {
        let mut doc = document(Json::obj(), vec![thread_meta(0, "bus")]);
        doc.set("series", Json::Arr(Vec::new()));
        let s = doc.to_string();
        assert!(s.starts_with(r#"{"displayTimeUnit":"ms","#), "got {s}");
        assert!(s.contains(r#""series":[]"#));
        assert!(s.contains(r#""traceEvents":["#));
    }
}
