//! Deterministic metrics primitives: integer counters, gauges and
//! fixed-edge log-bucket histograms in a name-keyed registry.
//!
//! This is the value layer under the fleet's telemetry subsystem
//! ([`crate::serve::telemetry`]): every quantity is a `u64` — counts,
//! last-set gauge values, and per-bucket tallies over power-of-two edges
//! — so a [`MetricsHub`] snapshot digests bit-for-bit into the fleet's
//! [`stats_digest`](crate::serve::FleetReport::stats_digest) with no
//! float tolerance anywhere, and two engines that observe the same
//! virtual-time history produce byte-identical registries. The registry
//! is an ordinary [`BTreeMap`], so iteration, JSON rendering and digest
//! folding all walk names in one deterministic (sorted) order.
//!
//! The bucket layout is fixed at compile time ([`HIST_BUCKETS`] edges at
//! `0, 1, 2, 4, 8, ...`): histograms from different runs are always
//! bucket-compatible, which is what lets CI diff and gate them.
//!
//! The [`chrome`] submodule is the companion export layer: the single
//! construction path for Chrome trace-event JSON shared by the schedule
//! trace and the fleet telemetry exporters.

pub mod chrome;

use std::collections::BTreeMap;

use crate::util::fnv1a;
use crate::util::json::Json;

/// Number of log-spaced buckets every [`Histogram`] carries.
pub const HIST_BUCKETS: usize = 32;

/// The bucket a value falls into: bucket 0 holds zero, bucket `i >= 1`
/// holds `[2^(i-1), 2^i)`, and the last bucket absorbs everything at or
/// above its lower edge.
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive lower edge of bucket `i` (`0, 1, 2, 4, 8, ...`).
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// A fixed-edge log-bucket histogram of `u64` samples. Integer counts
/// only; the mean is recoverable from `sum / count`, and tails from the
/// bucket counts — no stored floats, so it digests exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; HIST_BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Record the same sample `n` times — exactly equivalent to calling
    /// [`Histogram::record`] `n` times (integer state throughout), which
    /// is what lets the event-driven fleet engine fold an idle span into
    /// one call without perturbing the digest.
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.counts[bucket_of(v)] += n;
        self.count += n;
        self.sum = self.sum.saturating_add(v.saturating_mul(n));
        self.max = self.max.max(v);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Largest sample seen (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// The per-bucket counts (see [`bucket_lo`] for the edges).
    pub fn bucket_counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.counts
    }

    /// The histogram folded to digest words: count, sum, max, then every
    /// bucket count in edge order.
    pub fn digest_words(&self) -> impl Iterator<Item = u64> + '_ {
        [self.count, self.sum, self.max].into_iter().chain(self.counts.iter().copied())
    }

    /// Deterministic JSON: totals plus the non-empty buckets as
    /// `[lower_edge, count]` pairs in edge order.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::Num(bucket_lo(i) as f64), Json::Num(c as f64)])
            })
            .collect();
        let mut o = Json::obj();
        o.set("count", Json::Num(self.count as f64))
            .set("sum", Json::Num(self.sum as f64))
            .set("max", Json::Num(self.max as f64))
            .set("buckets", Json::Arr(buckets));
        o
    }
}

/// One registered metric: a monotone counter, a last-value gauge, or a
/// log-bucket [`Histogram`].
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    /// Monotonically accumulated count.
    Counter(u64),
    /// Last value set.
    Gauge(u64),
    /// Distribution over the fixed log-bucket edges.
    Histogram(Histogram),
}

impl MetricValue {
    /// Stable kind name (`counter` / `gauge` / `histogram`).
    pub fn kind(&self) -> &'static str {
        match self {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
        }
    }
}

/// A name-keyed registry of [`MetricValue`]s with deterministic (sorted)
/// iteration, digesting and JSON rendering. Writing through a name whose
/// registered kind differs replaces the entry — callers own their
/// namespace, and the fleet's recorder uses fixed names throughout.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsHub {
    metrics: BTreeMap<String, MetricValue>,
}

impl MetricsHub {
    /// An empty hub.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to the counter `name` (registering it at zero first).
    pub fn inc(&mut self, name: &str, by: u64) {
        match self.metrics.get_mut(name) {
            Some(MetricValue::Counter(c)) => *c += by,
            _ => {
                self.metrics.insert(name.to_string(), MetricValue::Counter(by));
            }
        }
    }

    /// Set the gauge `name` to `v`.
    pub fn set(&mut self, name: &str, v: u64) {
        self.metrics.insert(name.to_string(), MetricValue::Gauge(v));
    }

    /// Record `v` into the histogram `name` (registering it empty first).
    pub fn observe(&mut self, name: &str, v: u64) {
        match self.metrics.get_mut(name) {
            Some(MetricValue::Histogram(h)) => h.record(v),
            _ => {
                let mut h = Histogram::new();
                h.record(v);
                self.metrics.insert(name.to_string(), MetricValue::Histogram(h));
            }
        }
    }

    /// Record `v` into the histogram `name` `n` times — equivalent to
    /// `n` [`MetricsHub::observe`] calls (see [`Histogram::record_n`]).
    pub fn observe_n(&mut self, name: &str, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        match self.metrics.get_mut(name) {
            Some(MetricValue::Histogram(h)) => h.record_n(v, n),
            _ => {
                let mut h = Histogram::new();
                h.record_n(v, n);
                self.metrics.insert(name.to_string(), MetricValue::Histogram(h));
            }
        }
    }

    /// The counter `name`, or 0 when absent (or not a counter).
    pub fn counter(&self, name: &str) -> u64 {
        match self.metrics.get(name) {
            Some(MetricValue::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// The gauge `name`, if registered as one.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        match self.metrics.get(name) {
            Some(MetricValue::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    /// The histogram `name`, if registered as one.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        match self.metrics.get(name) {
            Some(MetricValue::Histogram(h)) => Some(h),
            _ => None,
        }
    }

    /// Every metric in sorted-name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &MetricValue)> {
        self.metrics.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// Whether nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// The whole registry folded to digest words, in sorted-name order:
    /// per metric a name hash, a kind code, and the value words.
    pub fn digest_words(&self) -> Vec<u64> {
        let mut words = vec![self.metrics.len() as u64];
        for (name, m) in &self.metrics {
            words.push(fnv1a(name.bytes().map(u64::from)));
            match m {
                MetricValue::Counter(c) => words.extend([1, *c]),
                MetricValue::Gauge(v) => words.extend([2, *v]),
                MetricValue::Histogram(h) => {
                    words.push(3);
                    words.extend(h.digest_words());
                }
            }
        }
        words
    }

    /// Deterministic JSON: one object keyed by metric name, each entry
    /// carrying its kind and value.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (name, m) in &self.metrics {
            let mut e = Json::obj();
            e.set("kind", Json::Str(m.kind().into()));
            match m {
                MetricValue::Counter(c) => {
                    e.set("value", Json::Num(*c as f64));
                }
                MetricValue::Gauge(v) => {
                    e.set("value", Json::Num(*v as f64));
                }
                MetricValue::Histogram(h) => {
                    e.set("value", h.to_json());
                }
            }
            o.set(name, e);
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Every lower edge lands in its own bucket.
        assert_eq!(bucket_of(bucket_lo(0)), 0);
        for i in 1..HIST_BUCKETS {
            assert_eq!(bucket_of(bucket_lo(i)), i);
        }
    }

    #[test]
    fn histogram_counts_and_totals() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 1, 3, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1005);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.bucket_counts()[0], 1);
        assert_eq!(h.bucket_counts()[1], 2);
        assert_eq!(h.bucket_counts()[2], 1);
        assert_eq!(h.bucket_counts()[bucket_of(1000)], 1);
    }

    #[test]
    fn record_n_equals_n_records() {
        let mut one = Histogram::new();
        let mut batch = Histogram::new();
        for v in [0u64, 0, 0, 7, 7, 1024] {
            one.record(v);
        }
        batch.record_n(0, 3);
        batch.record_n(7, 2);
        batch.record_n(1024, 1);
        batch.record_n(99, 0); // n = 0 is a no-op
        assert_eq!(one, batch);

        let mut a = MetricsHub::new();
        let mut b = MetricsHub::new();
        for _ in 0..5 {
            a.observe("h", 0);
        }
        b.observe_n("h", 0, 5);
        assert_eq!(a.digest_words(), b.digest_words());
    }

    #[test]
    fn hub_counter_gauge_histogram() {
        let mut hub = MetricsHub::new();
        hub.inc("a.count", 2);
        hub.inc("a.count", 3);
        hub.set("b.gauge", 7);
        hub.set("b.gauge", 9);
        hub.observe("c.hist", 4);
        hub.observe("c.hist", 5);
        assert_eq!(hub.counter("a.count"), 5);
        assert_eq!(hub.gauge("b.gauge"), Some(9));
        assert_eq!(hub.histogram("c.hist").unwrap().count(), 2);
        assert_eq!(hub.counter("missing"), 0);
        assert_eq!(hub.len(), 3);
        // Iteration is sorted by name.
        let names: Vec<&str> = hub.iter().map(|(n, _)| n).collect();
        assert_eq!(names, ["a.count", "b.gauge", "c.hist"]);
    }

    #[test]
    fn digest_is_order_free_and_value_sensitive() {
        let mut a = MetricsHub::new();
        a.inc("x", 1);
        a.set("y", 2);
        let mut b = MetricsHub::new();
        b.set("y", 2);
        b.inc("x", 1);
        assert_eq!(a.digest_words(), b.digest_words(), "insertion order must not matter");
        b.inc("x", 1);
        assert_ne!(a.digest_words(), b.digest_words(), "values must matter");
    }

    #[test]
    fn json_is_deterministic_and_parses() {
        let mut hub = MetricsHub::new();
        hub.inc("plan_cache.hits", 12);
        hub.observe("frame.latency_us", 1500);
        let a = hub.to_json().to_string();
        let b = hub.to_json().to_string();
        assert_eq!(a, b);
        let doc = Json::parse(&a).expect("valid JSON");
        assert_eq!(
            doc.get("plan_cache.hits").and_then(|m| m.get("value")).and_then(Json::as_u64),
            Some(12)
        );
        assert_eq!(
            doc.get("frame.latency_us")
                .and_then(|m| m.get("value"))
                .and_then(|v| v.get("count"))
                .and_then(Json::as_u64),
            Some(1)
        );
    }
}
