//! Detection post-processing: anchor decode, NMS, VOC mAP.
//!
//! Runs entirely in rust on the request path (the chip does the same in
//! its host software — the DLA emits the raw head tensor).

pub mod anchors;
pub mod decode;
pub mod map;
pub mod nms;

pub use anchors::{best_anchor, ANCHORS};
pub use decode::{decode, Detection};
pub use map::{average_precision, mean_average_precision, GroundTruth};
pub use nms::nms;

/// An axis-aligned box, normalized to [0,1] image coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    /// Center x.
    pub cx: f32,
    /// Center y.
    pub cy: f32,
    /// Width.
    pub w: f32,
    /// Height.
    pub h: f32,
}

impl BBox {
    /// Left edge.
    pub fn x0(&self) -> f32 {
        self.cx - self.w / 2.0
    }
    /// Top edge.
    pub fn y0(&self) -> f32 {
        self.cy - self.h / 2.0
    }
    /// Right edge.
    pub fn x1(&self) -> f32 {
        self.cx + self.w / 2.0
    }
    /// Bottom edge.
    pub fn y1(&self) -> f32 {
        self.cy + self.h / 2.0
    }

    /// Box area (clamped non-negative).
    pub fn area(&self) -> f32 {
        self.w.max(0.0) * self.h.max(0.0)
    }

    /// Intersection-over-union.
    pub fn iou(&self, o: &BBox) -> f32 {
        let ix = (self.x1().min(o.x1()) - self.x0().max(o.x0())).max(0.0);
        let iy = (self.y1().min(o.y1()) - self.y0().max(o.y0())).max(0.0);
        let inter = ix * iy;
        let union = self.area() + o.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_identity() {
        let b = BBox { cx: 0.5, cy: 0.5, w: 0.2, h: 0.2 };
        assert!((b.iou(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint() {
        let a = BBox { cx: 0.2, cy: 0.2, w: 0.1, h: 0.1 };
        let b = BBox { cx: 0.8, cy: 0.8, w: 0.1, h: 0.1 };
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        let a = BBox { cx: 0.5, cy: 0.5, w: 0.2, h: 0.2 };
        let b = BBox { cx: 0.6, cy: 0.5, w: 0.2, h: 0.2 };
        // Intersection 0.1x0.2, union 0.04+0.04-0.02.
        assert!((a.iou(&b) - (0.02 / 0.06)).abs() < 1e-6);
    }
}
