//! VOC-style mean average precision (the paper's accuracy metric).
//!
//! 11-point interpolated AP at IoU 0.5 (the PASCAL VOC 2007 protocol the
//! paper evaluates with), averaged over classes.

use super::{BBox, Detection};

/// A ground-truth box with its class and image id.
#[derive(Debug, Clone, Copy)]
pub struct GroundTruth {
    /// Image id the box belongs to.
    pub image: usize,
    /// Class index.
    pub class: usize,
    /// The box.
    pub bbox: BBox,
}

/// Detection tagged with its image id.
#[derive(Debug, Clone)]
pub struct TaggedDetection {
    /// Image id the detection was made on.
    pub image: usize,
    /// The detection.
    pub det: Detection,
}

/// 11-point interpolated AP for one class.
pub fn average_precision(
    dets: &[TaggedDetection],
    gts: &[GroundTruth],
    class: usize,
    iou_thr: f32,
) -> f32 {
    let gt: Vec<&GroundTruth> = gts.iter().filter(|g| g.class == class).collect();
    if gt.is_empty() {
        return 0.0;
    }
    let mut ds: Vec<&TaggedDetection> =
        dets.iter().filter(|d| d.det.class == class).collect();
    ds.sort_by(|a, b| b.det.score.partial_cmp(&a.det.score).unwrap());

    let mut matched = vec![false; gt.len()];
    let mut tp = Vec::with_capacity(ds.len());
    for d in &ds {
        // Best unmatched GT in the same image.
        let mut best: Option<(usize, f32)> = None;
        for (gi, g) in gt.iter().enumerate() {
            if g.image != d.image || matched[gi] {
                continue;
            }
            let iou = g.bbox.iou(&d.det.bbox);
            if iou >= iou_thr && best.map_or(true, |(_, b)| iou > b) {
                best = Some((gi, iou));
            }
        }
        match best {
            Some((gi, _)) => {
                matched[gi] = true;
                tp.push(true);
            }
            None => tp.push(false),
        }
    }

    // Precision-recall curve.
    let mut cum_tp = 0usize;
    let mut prec = Vec::with_capacity(tp.len());
    let mut rec = Vec::with_capacity(tp.len());
    for (i, &t) in tp.iter().enumerate() {
        if t {
            cum_tp += 1;
        }
        prec.push(cum_tp as f32 / (i + 1) as f32);
        rec.push(cum_tp as f32 / gt.len() as f32);
    }

    // 11-point interpolation.
    let mut ap = 0.0;
    for k in 0..=10 {
        let r = k as f32 / 10.0;
        let p = prec
            .iter()
            .zip(&rec)
            .filter(|(_, &rr)| rr >= r)
            .map(|(&pp, _)| pp)
            .fold(0.0f32, f32::max);
        ap += p / 11.0;
    }
    ap
}

/// mAP over `classes`.
pub fn mean_average_precision(
    dets: &[TaggedDetection],
    gts: &[GroundTruth],
    classes: usize,
    iou_thr: f32,
) -> f32 {
    if classes == 0 {
        return 0.0;
    }
    (0..classes)
        .map(|c| average_precision(dets, gts, c, iou_thr))
        .sum::<f32>()
        / classes as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gt(image: usize, class: usize, cx: f32) -> GroundTruth {
        GroundTruth { image, class, bbox: BBox { cx, cy: 0.5, w: 0.2, h: 0.2 } }
    }

    fn det(image: usize, class: usize, cx: f32, score: f32) -> TaggedDetection {
        TaggedDetection {
            image,
            det: Detection { bbox: BBox { cx, cy: 0.5, w: 0.2, h: 0.2 }, class, score },
        }
    }

    #[test]
    fn perfect_detections_ap_1() {
        let gts = vec![gt(0, 0, 0.3), gt(0, 0, 0.7), gt(1, 0, 0.5)];
        let dets = vec![det(0, 0, 0.3, 0.9), det(0, 0, 0.7, 0.8), det(1, 0, 0.5, 0.95)];
        let ap = average_precision(&dets, &gts, 0, 0.5);
        assert!((ap - 1.0).abs() < 1e-5, "{ap}");
    }

    #[test]
    fn misses_reduce_ap() {
        let gts = vec![gt(0, 0, 0.3), gt(0, 0, 0.7)];
        let dets = vec![det(0, 0, 0.3, 0.9)];
        let ap = average_precision(&dets, &gts, 0, 0.5);
        assert!(ap < 0.6, "{ap}");
        assert!(ap > 0.3, "{ap}");
    }

    #[test]
    fn false_positives_reduce_ap() {
        let gts = vec![gt(0, 0, 0.3)];
        let dets = vec![
            det(0, 0, 0.9, 0.99), // FP ranked first
            det(0, 0, 0.3, 0.5),
        ];
        let ap = average_precision(&dets, &gts, 0, 0.5);
        assert!(ap < 0.75, "{ap}");
    }

    #[test]
    fn duplicate_detection_counts_once() {
        let gts = vec![gt(0, 0, 0.3)];
        let dets = vec![det(0, 0, 0.3, 0.9), det(0, 0, 0.31, 0.85)];
        let ap = average_precision(&dets, &gts, 0, 0.5);
        assert!(ap <= 1.0001 && ap > 0.9, "{ap}"); // 11-pt interp: max-precision at recall>=r
    }

    #[test]
    fn no_ground_truths_is_zero_not_nan() {
        // With zero GT boxes the recall denominator would be 0; the
        // guard must return 0 rather than divide by zero.
        let dets = vec![det(0, 0, 0.3, 0.9)];
        let ap = average_precision(&dets, &[], 0, 0.5);
        assert_eq!(ap, 0.0);
        assert!(ap.is_finite());
        let m = mean_average_precision(&dets, &[], 3, 0.5);
        assert_eq!(m, 0.0);
        assert!(m.is_finite());
    }

    #[test]
    fn zero_classes_map_is_zero() {
        let gts = vec![gt(0, 0, 0.3)];
        assert_eq!(mean_average_precision(&[], &gts, 0, 0.5), 0.0);
    }

    #[test]
    fn map_averages_classes() {
        let gts = vec![gt(0, 0, 0.3), gt(0, 1, 0.7)];
        let dets = vec![det(0, 0, 0.3, 0.9)]; // only class 0 detected
        let m = mean_average_precision(&dets, &gts, 2, 0.5);
        assert!((m - 0.5).abs() < 0.05, "{m}");
    }
}
