//! Anchor set — MUST match `python/compile/detect.py` (the trainer
//! assigns targets with the same table and rule).

/// Normalized (w, h) anchor sizes.
pub const ANCHORS: [(f32, f32); 5] = [
    (0.08, 0.10),
    (0.18, 0.20),
    (0.32, 0.32),
    (0.45, 0.28),
    (0.28, 0.45),
];

/// Anchor with the closest size (L2 in wh space).
pub fn best_anchor(w: f32, h: f32) -> usize {
    let mut best = 0;
    let mut bd = f32::MAX;
    for (i, (aw, ah)) in ANCHORS.iter().enumerate() {
        let d = (w - aw).powi(2) + (h - ah).powi(2);
        if d < bd {
            bd = d;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_sizes_pick_themselves() {
        for (i, (w, h)) in ANCHORS.iter().enumerate() {
            assert_eq!(best_anchor(*w, *h), i);
        }
    }

    #[test]
    fn small_box_picks_small_anchor() {
        assert_eq!(best_anchor(0.05, 0.08), 0);
    }
}
