//! Per-class greedy non-maximum suppression.

use super::Detection;

/// Suppress detections overlapping a higher-scoring detection of the
/// same class by more than `iou_threshold`. Returns survivors sorted by
/// descending score.
pub fn nms(mut dets: Vec<Detection>, iou_threshold: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::with_capacity(dets.len());
    for d in dets {
        let suppressed = keep
            .iter()
            .any(|k| k.class == d.class && k.bbox.iou(&d.bbox) > iou_threshold);
        if !suppressed {
            keep.push(d);
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::BBox;

    fn det(cx: f32, score: f32, class: usize) -> Detection {
        Detection { bbox: BBox { cx, cy: 0.5, w: 0.2, h: 0.2 }, class, score }
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let kept = nms(vec![det(0.50, 0.9, 0), det(0.52, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.9);
    }

    #[test]
    fn keeps_different_classes() {
        let kept = nms(vec![det(0.50, 0.9, 0), det(0.52, 0.8, 1)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn keeps_distant_boxes() {
        let kept = nms(vec![det(0.2, 0.9, 0), det(0.8, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn sorted_by_score() {
        let kept = nms(vec![det(0.2, 0.5, 0), det(0.8, 0.9, 0)], 0.5);
        assert!(kept[0].score >= kept[1].score);
    }

    #[test]
    fn zero_detections() {
        assert!(nms(Vec::new(), 0.5).is_empty());
    }

    #[test]
    fn tied_scores_distant_both_survive() {
        // sort_by is stable: equal scores keep insertion order, so the
        // outcome is deterministic, not an unordered-float panic.
        let kept = nms(vec![det(0.2, 0.8, 0), det(0.8, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 2);
        assert!((kept[0].bbox.cx - 0.2).abs() < 1e-6);
        assert!((kept[1].bbox.cx - 0.8).abs() < 1e-6);
    }

    #[test]
    fn tied_scores_overlapping_keeps_first() {
        let kept = nms(vec![det(0.50, 0.8, 0), det(0.51, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 1);
        assert!((kept[0].bbox.cx - 0.50).abs() < 1e-6);
    }
}
