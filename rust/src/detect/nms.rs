//! Per-class greedy non-maximum suppression.

use super::Detection;

/// Suppress detections overlapping a higher-scoring detection of the
/// same class by more than `iou_threshold`. Returns survivors sorted by
/// descending score.
pub fn nms(mut dets: Vec<Detection>, iou_threshold: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::with_capacity(dets.len());
    for d in dets {
        let suppressed = keep
            .iter()
            .any(|k| k.class == d.class && k.bbox.iou(&d.bbox) > iou_threshold);
        if !suppressed {
            keep.push(d);
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::BBox;

    fn det(cx: f32, score: f32, class: usize) -> Detection {
        Detection { bbox: BBox { cx, cy: 0.5, w: 0.2, h: 0.2 }, class, score }
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let kept = nms(vec![det(0.50, 0.9, 0), det(0.52, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.9);
    }

    #[test]
    fn keeps_different_classes() {
        let kept = nms(vec![det(0.50, 0.9, 0), det(0.52, 0.8, 1)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn keeps_distant_boxes() {
        let kept = nms(vec![det(0.2, 0.9, 0), det(0.8, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn sorted_by_score() {
        let kept = nms(vec![det(0.2, 0.5, 0), det(0.8, 0.9, 0)], 0.5);
        assert!(kept[0].score >= kept[1].score);
    }
}
