//! Head-tensor decode — mirrors `python/compile/detect.py` semantics:
//! per cell (row, col) and anchor a, the head emits
//! `[tx, ty, tw, th, to, class logits...]`:
//!   cx = (col + sigmoid(tx)) / gw,  bw = anchor_w * exp(clip(tw, ±4))
//!   objectness = sigmoid(to),       class = argmax softmax(logits)

use super::anchors::ANCHORS;
use super::BBox;

/// One decoded detection.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Decoded box in normalized image coordinates.
    pub bbox: BBox,
    /// Argmax class index.
    pub class: usize,
    /// objectness x class probability.
    pub score: f32,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode a raw head tensor (row-major `(gh, gw, A*(5+classes))` f32)
/// into detections above `conf_threshold`.
pub fn decode(
    head: &[f32],
    gh: usize,
    gw: usize,
    classes: usize,
    conf_threshold: f32,
) -> Vec<Detection> {
    let a = ANCHORS.len();
    let stride_cell = a * (5 + classes);
    debug_assert_eq!(head.len(), gh * gw * stride_cell);
    let mut out = Vec::new();
    for row in 0..gh {
        for col in 0..gw {
            let base_cell = (row * gw + col) * stride_cell;
            for k in 0..a {
                let b = base_cell + k * (5 + classes);
                let (tx, ty, tw, th, to) = (head[b], head[b + 1], head[b + 2], head[b + 3], head[b + 4]);
                let obj = sigmoid(to);
                if obj < conf_threshold {
                    continue;
                }
                // Class via softmax argmax; score = obj * p(class).
                let logits = &head[b + 5..b + 5 + classes];
                let max_l = logits.iter().cloned().fold(f32::MIN, f32::max);
                let exps: Vec<f32> = logits.iter().map(|l| (l - max_l).exp()).collect();
                let sum: f32 = exps.iter().sum();
                let (class, p) = exps
                    .iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .map(|(i, e)| (i, e / sum))
                    .unwrap();
                let score = obj * p;
                if score < conf_threshold {
                    continue;
                }
                let (aw, ah) = ANCHORS[k];
                out.push(Detection {
                    bbox: BBox {
                        cx: (col as f32 + sigmoid(tx)) / gw as f32,
                        cy: (row as f32 + sigmoid(ty)) / gh as f32,
                        w: aw * tw.clamp(-4.0, 4.0).exp(),
                        h: ah * th.clamp(-4.0, 4.0).exp(),
                    },
                    class,
                    score,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_with_one_box(gh: usize, gw: usize, classes: usize) -> Vec<f32> {
        let a = ANCHORS.len();
        let mut head = vec![-10.0f32; gh * gw * a * (5 + classes)];
        // Activate cell (1, 2), anchor 1, class 2.
        let b = ((1 * gw + 2) * a + 1) * (5 + classes);
        head[b] = 0.0; // tx -> 0.5
        head[b + 1] = 0.0;
        head[b + 2] = 0.0; // tw -> anchor size
        head[b + 3] = 0.0;
        head[b + 4] = 8.0; // high objectness
        head[b + 5 + 2] = 6.0;
        head
    }

    #[test]
    fn decodes_single_box() {
        let (gh, gw, classes) = (4, 6, 3);
        let dets = decode(&head_with_one_box(gh, gw, classes), gh, gw, classes, 0.5);
        assert_eq!(dets.len(), 1);
        let d = &dets[0];
        assert_eq!(d.class, 2);
        assert!((d.bbox.cx - 2.5 / 6.0).abs() < 1e-6);
        assert!((d.bbox.cy - 1.5 / 4.0).abs() < 1e-6);
        assert!((d.bbox.w - ANCHORS[1].0).abs() < 1e-6);
        assert!(d.score > 0.9);
    }

    #[test]
    fn threshold_filters() {
        let (gh, gw, classes) = (4, 6, 3);
        let dets = decode(&head_with_one_box(gh, gw, classes), gh, gw, classes, 0.9999);
        assert!(dets.is_empty());
    }

    #[test]
    fn all_cells_below_threshold() {
        // A head with uniformly low objectness everywhere must decode to
        // nothing at any sane threshold — the empty-frame fast path.
        let (gh, gw, classes) = (4, 6, 3);
        let head = vec![-10.0f32; gh * gw * ANCHORS.len() * (5 + classes)];
        assert!(decode(&head, gh, gw, classes, 0.25).is_empty());
        assert!(decode(&head, gh, gw, classes, 0.01).is_empty());
    }
}
