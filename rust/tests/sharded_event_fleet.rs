//! Sharded-event-engine acceptance pins: the sharded discrete-event
//! fleet engine (`FleetSim::run_event_sharded`, selected with
//! `Engine::EventSharded`) must be a byte-identical replacement for the
//! serial per-tick oracle — stats digest, report text, JSON document
//! and exported Chrome trace — on every bundled preset across seeds
//! AND across worker counts {2, 3, 8} (shard boundaries land in
//! different places each time), plus a reduced slice of the
//! metro-scale preset. Reruns of the sharded engine must also be
//! stable against themselves.

use rcnet_dla::serve::{
    run_fleet, AdmissionPolicy, Engine, FleetConfig, FleetReport, Scenario, PRESET_NAMES,
};

fn preset_cfg(name: &str, seed: u64, engine: Engine, threads: usize) -> FleetConfig {
    // 2 s spans rush-hour's whole churn window (same choice as
    // tests/event_fleet.rs), so arrivals, departures, faults and QoS
    // downshifts all fire mid-run under every worker count.
    FleetConfig {
        seconds: 2.0,
        seed,
        engine,
        threads,
        ..FleetConfig::new(Scenario::preset(name).expect("bundled preset"))
    }
}

/// Byte-identity oracle shared with `tests/event_fleet.rs`: digest plus
/// both human-facing documents plus the exported Chrome trace.
fn assert_identical(a: &FleetReport, b: &FleetReport, scenario: &str, what: &str) {
    assert_eq!(a.stats_digest(), b.stats_digest(), "stats digest diverged: {what}");
    assert_eq!(a.to_string(), b.to_string(), "report text diverged: {what}");
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "json document diverged: {what}"
    );
    let at = a.telemetry.as_ref().expect("telemetry on by default");
    let bt = b.telemetry.as_ref().expect("telemetry on in the sharded engine");
    assert_eq!(at.incidents, bt.incidents, "incident lists diverged: {what}");
    assert_eq!(
        at.to_chrome_json(scenario).to_string(),
        bt.to_chrome_json(scenario).to_string(),
        "chrome trace diverged: {what}"
    );
}

/// The headline pin: every bundled preset, two seeds, three worker
/// counts — the sharded event engine's report AND its exported Chrome
/// trace byte-match the serial reference. The serial oracle runs once
/// per (preset, seed); each worker count is compared against it, and a
/// rerun of one sharded count reproduces its own bytes.
#[test]
fn every_preset_is_byte_identical_sharded_vs_serial() {
    for name in PRESET_NAMES {
        for seed in [1u64, 7] {
            let serial =
                run_fleet(&preset_cfg(name, seed, Engine::Tick, 1)).expect("serial run");
            assert!(serial.released() > 0, "{name} seed {seed} released nothing");
            for workers in [2usize, 3, 8] {
                let sharded =
                    run_fleet(&preset_cfg(name, seed, Engine::EventSharded, workers))
                        .expect("sharded event run");
                assert_identical(
                    &serial,
                    &sharded,
                    name,
                    &format!("{name}, seed {seed}, {workers} workers"),
                );
            }
            let again = run_fleet(&preset_cfg(name, seed, Engine::EventSharded, 3))
                .expect("sharded event rerun");
            assert_eq!(
                serial.to_json().to_string(),
                again.to_json().to_string(),
                "{name} seed {seed}: sharded rerun json diverged"
            );
        }
    }
}

/// Load-level sweep with more workers than chips and more chips than
/// workers: shard shapes where some workers own zero chips (streams
/// only) and where one worker owns several. Overload engages expiry,
/// overflow shedding and dispatch backpressure — the phases where the
/// central heap's order must reproduce the serial scan exactly.
#[test]
fn sampled_fleets_are_identical_across_shard_shapes() {
    for &(streams, chips) in &[(6usize, 2usize), (24, 4), (64, 8)] {
        for seed in [1u64, 11] {
            for policy in [
                AdmissionPolicy::AdmitAll,
                AdmissionPolicy::DemandLimit { oversub: 2.0 },
            ] {
                let base = FleetConfig {
                    seconds: 1.0,
                    admission: policy,
                    ..FleetConfig::sampled(streams, chips, seed)
                };
                let serial = run_fleet(&base).expect("serial run");
                for workers in [2usize, 3, 8] {
                    let sharded = run_fleet(&FleetConfig {
                        engine: Engine::EventSharded,
                        threads: workers,
                        ..base.clone()
                    })
                    .expect("sharded event run");
                    assert_identical(
                        &serial,
                        &sharded,
                        &base.scenario.name,
                        &format!(
                            "sampled {streams}x{chips} seed {seed} {policy:?} \
                             {workers} workers"
                        ),
                    );
                }
            }
        }
    }
}

/// The metro slice: a reduced span of the 100k-stream preset, sharded.
/// Two workers keeps the debug-mode cost bounded; the full-span sharded
/// series lives in the bench family (`BENCH_metro.json`).
#[test]
fn metro_slice_matches_the_serial_oracle_sharded() {
    let base = FleetConfig {
        seconds: 0.3,
        ..FleetConfig::new(Scenario::preset("metro").expect("metro preset"))
    };
    let serial = run_fleet(&base).expect("serial metro slice");
    let sharded = run_fleet(&FleetConfig {
        engine: Engine::EventSharded,
        threads: 2,
        ..base
    })
    .expect("sharded metro slice");
    assert_eq!(serial.stats_digest(), sharded.stats_digest(), "metro slice: digest diverged");
    assert_eq!(serial.released(), sharded.released(), "metro slice: releases diverged");
    assert_eq!(serial.rejected, sharded.rejected, "metro slice: admission diverged");
    let stel = serial.telemetry.as_ref().expect("telemetry on by default");
    let etel = sharded.telemetry.as_ref().expect("telemetry on in the sharded engine");
    assert_eq!(
        stel.to_chrome_json("metro").to_string(),
        etel.to_chrome_json("metro").to_string(),
        "metro slice: chrome trace diverged"
    );
    assert!(serial.released() > 0, "the slice does real work");
    assert!(
        serial.per_stream.len() > 100_000,
        "metro really is metro-scale ({} streams)",
        serial.per_stream.len()
    );
}
