//! Cross-model planner properties: for every zoo model at every paper
//! resolution, the DP plan's fused DRAM traffic never exceeds the paper
//! greedy plan's, both planners' groups tile on the fabricated chip, the
//! DP's internal cost decomposition agrees with the traffic model, and
//! the deployed RC-YOLOv2 still reproduces the paper's ~0.15 GB/s HD30
//! feature-traffic figure under the optimal plan.

use rcnet_dla::config::ChipConfig;
use rcnet_dla::fusion::{atomic_units, FusionConfig, FusionGroup, Unit};
use rcnet_dla::model::zoo::{plan_fixtures, PAPER_RESOLUTIONS};
use rcnet_dla::plan::{partition_feat_bytes, Planner};
use rcnet_dla::report::spec::{build_deployment_spec, spec_to_network, PipelineProfile};
use rcnet_dla::tile::{plan_group, plan_network};
use rcnet_dla::traffic::TrafficModel;

/// A group that fails tile planning is acceptable only when no partition
/// could do better: it is a single atomic unit (cannot be split further —
/// residual atomicity) and even one of its rows overflows the unified
/// buffer half at this resolution. DeepLabv3's 2048-channel OS16 maps at
/// 1920x1080 are the one real instance (120 px x 2048 ch > 192 KB); the
/// paper itself never runs DeepLab beyond 513x513.
fn physically_untileable(units: &[Unit], g: &FusionGroup) -> bool {
    units.iter().any(|u| u.start == g.start && u.end == g.end)
}

#[test]
fn dp_never_worse_than_greedy_and_both_tile_across_the_zoo() {
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig::paper_default();
    for fx in plan_fixtures() {
        let net = (fx.build)();
        let units = atomic_units(&net);
        for hw in PAPER_RESOLUTIONS {
            let greedy = Planner::PaperGreedy.plan(&net, &cfg, &chip, hw);
            let dp = Planner::OptimalDp.plan(&net, &cfg, &chip, hw);
            assert!(
                dp.feat_bytes <= greedy.feat_bytes,
                "{} at {hw:?}: dp {} > greedy {}",
                fx.name,
                dp.feat_bytes,
                greedy.feat_bytes
            );
            for (name, groups) in [("greedy", &greedy.groups), ("optimal-dp", &dp.groups)] {
                for (gi, (t, g)) in
                    plan_network(&net, groups, hw, &chip).iter().zip(groups.iter()).enumerate()
                {
                    assert!(
                        t.is_ok() || physically_untileable(&units, g),
                        "{} {name} group {gi} at {hw:?} fails tiling and is splittable: {t:?}",
                        fx.name
                    );
                }
            }
        }
    }
}

#[test]
fn planners_never_cause_untileability() {
    // Sanity for the escape hatch above: every group that fails tile
    // planning must fail for a *physical* reason — a single atomic unit
    // whose rows overflow the buffer — never because a planner chose a
    // bad multi-unit cut.
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig::paper_default();
    for fx in plan_fixtures() {
        let net = (fx.build)();
        let units = atomic_units(&net);
        for hw in PAPER_RESOLUTIONS {
            for planner in [Planner::PaperGreedy, Planner::OptimalDp] {
                let p = planner.plan(&net, &cfg, &chip, hw);
                for g in &p.groups {
                    if plan_group(&net, g, hw, &chip).is_err() {
                        assert!(
                            physically_untileable(&units, g),
                            "{} {} at {hw:?}: multi-unit group {g:?} untileable",
                            fx.name,
                            planner.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn dp_clears_each_models_reduction_envelope() {
    // The optimal plan's *feature* traffic must beat layer-by-layer by at
    // least each fixture's envelope, at every paper resolution.
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig::paper_default();
    let tm = TrafficModel::paper_chip();
    for fx in plan_fixtures() {
        let net = (fx.build)();
        for hw in PAPER_RESOLUTIONS {
            let dp = Planner::OptimalDp.plan(&net, &cfg, &chip, hw);
            let lbl_feat = tm.layer_by_layer(&net, hw).feat_bytes();
            assert!(
                dp.feat_bytes as f64 * fx.min_feat_reduction <= lbl_feat as f64,
                "{} at {hw:?}: fused {} x {} > layerwise {}",
                fx.name,
                dp.feat_bytes,
                fx.min_feat_reduction,
                lbl_feat
            );
        }
    }
}

#[test]
fn decomposed_cost_equals_traffic_model_for_both_planners() {
    // The DP minimizes a per-group decomposition of the fused traffic; it
    // must agree byte-for-byte with TrafficModel::fused on every plan.
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig::paper_default();
    let tm = TrafficModel::paper_chip();
    for fx in plan_fixtures() {
        let net = (fx.build)();
        for planner in [Planner::PaperGreedy, Planner::OptimalDp] {
            let p = planner.plan(&net, &cfg, &chip, (416, 416));
            assert_eq!(
                partition_feat_bytes(&net, &p.groups, &chip, (416, 416)),
                tm.fused(&net, &p.groups, (416, 416)).feat_bytes(),
                "{} under {}",
                fx.name,
                planner.name()
            );
        }
    }
}

#[test]
fn deployed_rc_yolov2_meets_the_paper_hd30_feature_budget() {
    // Paper §I / Table IV: YOLOv2 feature traffic drops from ~2.9 GB/s to
    // ~0.15 GB/s at 1280x720@30 after conversion + fusion. The optimal
    // plan of the deployed (pruned) network must stay in that regime —
    // same order-of-magnitude tolerance as the existing traffic tests.
    let spec = build_deployment_spec(PipelineProfile::Hd, 3, 5, None, 7);
    let (net, _spec_groups) = spec_to_network(&spec).unwrap();
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig { slack: 0.0, ..FusionConfig::paper_default() };
    let greedy = Planner::PaperGreedy.plan(&net, &cfg, &chip, (720, 1280));
    let dp = Planner::OptimalDp.plan(&net, &cfg, &chip, (720, 1280));
    assert!(dp.feat_bytes <= greedy.feat_bytes);
    let feat_mb_s = dp.feat_bytes as f64 * 30.0 / 1e6;
    assert!(
        (20.0..450.0).contains(&feat_mb_s),
        "optimal HD30 feature traffic {feat_mb_s:.1} MB/s is out of the paper's regime"
    );
}
