//! The bench regression gate, end to end through the on-disk format:
//! emit a baseline `BENCH_*.json`, reload it, and verify an injected 2x
//! slowdown (and only a genuine worsening) trips the gate that makes
//! `bench --against` exit nonzero.

use std::path::PathBuf;

use rcnet_dla::bench::{
    compare_reports, fingerprint_hex, BenchReport, Direction, Measurement, Metric,
};

fn sample_report() -> BenchReport {
    let mut rep = BenchReport::new("fleet", true);
    rep.measurements.push(Measurement {
        id: "fleet/chips=8/streams=64/sec=1/seed=1/threads=auto".into(),
        wall_ms: 120.0,
        fingerprint: fingerprint_hex([8, 64, 1]),
        metrics: vec![
            Metric { name: "virtual_throughput_fps".into(), value: 950.0, better: Direction::Higher },
            Metric { name: "p99_ms".into(), value: 45.0, better: Direction::Lower },
            Metric { name: "miss_rate".into(), value: 0.02, better: Direction::Lower },
            Metric { name: "admitted".into(), value: 64.0, better: Direction::Info },
        ],
    });
    rep.measurements.push(Measurement {
        id: "plan-cache/warm-hits-x1000".into(),
        wall_ms: 0.8,
        fingerprint: String::new(),
        metrics: Vec::new(),
    });
    rep
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rcnet-bench-{}-{name}", std::process::id()))
}

#[test]
fn baseline_round_trips_through_disk() {
    let rep = sample_report();
    let path = temp_path("roundtrip.json");
    rep.write(&path).expect("write baseline");
    let loaded = BenchReport::load(&path).expect("load baseline");
    std::fs::remove_file(&path).ok();
    assert_eq!(rep, loaded);
    // And the self-comparison passes with zero drift.
    let out = compare_reports(&loaded, &rep, 0.15);
    assert!(out.passed());
    assert_eq!(out.compared, 2);
    assert!(out.fingerprint_drift.is_empty());
}

#[test]
fn injected_2x_slowdown_fails_the_gate() {
    let baseline = sample_report();
    let path = temp_path("slowdown.json");
    baseline.write(&path).expect("write baseline");
    let baseline = BenchReport::load(&path).expect("load baseline");
    std::fs::remove_file(&path).ok();

    let mut current = sample_report();
    for m in &mut current.measurements {
        m.wall_ms *= 2.0; // the injected slowdown
    }
    let out = compare_reports(&baseline, &current, 0.15);
    assert!(!out.passed(), "a 2x slowdown must gate");
    // Every measurement's wall time regressed.
    assert_eq!(out.regressions.len(), 2);
    assert!(out.regressions.iter().all(|r| r.metric == "wall_ms"));
    assert!(out.regressions.iter().all(|r| (r.ratio - 2.0).abs() < 1e-9));
    // This outcome is exactly what makes the CLI `bench --against`
    // bail out with a nonzero exit status.
    let text = out.render("fleet", 0.15);
    assert!(text.contains("FAIL"));
    assert!(text.contains("REGRESSION"));
}

#[test]
fn deterministic_metric_drift_gates_and_fingerprints_warn() {
    let baseline = sample_report();

    // p99 worsens 60% — gated even with wall times unchanged.
    let mut worse = sample_report();
    worse.measurements[0].metrics[1].value = 72.0;
    let out = compare_reports(&baseline, &worse, 0.15);
    assert!(!out.passed());
    assert_eq!(out.regressions[0].metric, "p99_ms");

    // Info metrics never gate.
    let mut info = sample_report();
    info.measurements[0].metrics[3].value = 1.0;
    assert!(compare_reports(&baseline, &info, 0.15).passed());

    // Fingerprint drift alone warns but does not gate.
    let mut drifted = sample_report();
    drifted.measurements[0].fingerprint = fingerprint_hex([9, 9, 9]);
    let out = compare_reports(&baseline, &drifted, 0.15);
    assert!(out.passed());
    assert_eq!(out.fingerprint_drift.len(), 1);
}

#[test]
fn bootstrap_baseline_file_passes_trivially() {
    // The exact shape committed at the repo root before the first real
    // baseline lands: bootstrap = true, no measurements.
    let txt = r#"{"schema":"rcnet-dla/bench/v1","kind":"fleet","quick":true,"bootstrap":true,"measurements":[]}"#;
    let path = temp_path("bootstrap.json");
    std::fs::write(&path, txt).expect("write bootstrap baseline");
    let baseline = BenchReport::load(&path).expect("load bootstrap baseline");
    std::fs::remove_file(&path).ok();
    assert!(baseline.bootstrap);
    let out = compare_reports(&baseline, &sample_report(), 0.15);
    assert!(out.passed());
    assert_eq!(out.compared, 0);
    assert_eq!(out.new_ids.len(), 2);
}

#[test]
fn corrupt_or_wrong_schema_files_are_rejected() {
    let path = temp_path("corrupt.json");
    std::fs::write(&path, "{not json").expect("write");
    assert!(BenchReport::load(&path).is_err());
    std::fs::write(&path, r#"{"schema":"other/v9","kind":"fleet","measurements":[]}"#)
        .expect("write");
    assert!(BenchReport::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}
