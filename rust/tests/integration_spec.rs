//! Integration over the spec pipeline: deployment spec emission, JSON
//! round-trip, cross-checks between the traffic model, the simulator and
//! the tile planner on the deployed network.

use rcnet_dla::config::ChipConfig;
use rcnet_dla::dla::{simulate_fused, simulate_layer_by_layer};
use rcnet_dla::fusion::{validate_groups, FusionConfig};
use rcnet_dla::report::spec::{build_deployment_spec, spec_to_network, PipelineProfile};
use rcnet_dla::traffic::TrafficModel;
use rcnet_dla::util::json::Json;

#[test]
fn deployment_spec_roundtrip_and_validation() {
    for profile in [PipelineProfile::Scaled, PipelineProfile::Hd] {
        let spec = build_deployment_spec(profile, 3, 5, None, 7);
        let txt = spec.to_string();
        let (net, groups) = spec_to_network(&Json::parse(&txt).unwrap()).unwrap();
        assert!(net.check_consistency().is_empty());
        let v = validate_groups(&net, &groups, &FusionConfig::paper_default());
        assert!(v.is_empty(), "{profile:?}: {v:?}");
    }
}

#[test]
fn spec_is_deterministic() {
    let a = build_deployment_spec(PipelineProfile::Scaled, 3, 5, None, 7).to_string();
    let b = build_deployment_spec(PipelineProfile::Scaled, 3, 5, None, 7).to_string();
    assert_eq!(a, b);
}

#[test]
fn simulator_and_traffic_model_agree_on_dram_bytes() {
    let spec = build_deployment_spec(PipelineProfile::Hd, 3, 5, None, 7);
    let (net, groups) = spec_to_network(&spec).unwrap();
    let chip = ChipConfig::paper_chip();
    let tm = TrafficModel::paper_chip();
    for hw in [(416u32, 416u32), (720, 1280), (1080, 1920)] {
        let (sim, _) = simulate_fused(&net, &groups, hw, &chip).unwrap();
        assert_eq!(sim.total_dram_bytes(), tm.fused(&net, &groups, hw).total_bytes());
        let lbl = simulate_layer_by_layer(&net, hw, &chip);
        assert_eq!(lbl.total_dram_bytes(), tm.layer_by_layer(&net, hw).total_bytes());
        assert_eq!(sim.total_macs(), net.macs(hw));
    }
}

#[test]
fn headline_numbers_in_paper_regime() {
    // The end-to-end claim set, asserted as a regression fence:
    // traffic reduction 5-10x, >80% savings, HD real-time regime.
    let spec = build_deployment_spec(PipelineProfile::Hd, 3, 5, None, 7);
    let (net, groups) = spec_to_network(&spec).unwrap();
    let tm = TrafficModel::paper_chip();
    let (lbl, fus) = tm.compare(&net, &groups, (720, 1280), 30.0);
    let reduction = lbl.total_mb_s() / fus.total_mb_s();
    assert!((4.0..12.0).contains(&reduction), "reduction {reduction}");
    assert!(fus.total_mb_s() < 1200.0, "fused {}", fus.total_mb_s());
    let chip = ChipConfig::paper_chip();
    let (sim, _) = simulate_fused(&net, &groups, (720, 1280), &chip).unwrap();
    assert!(sim.fps() > 18.0, "fps {}", sim.fps());
}
