//! Telemetry acceptance pins: for every bundled preset the telemetry
//! document — Chrome trace JSON, window series, and incident list — is
//! byte-identical across repeated runs and across serial vs parallel
//! engines at every thread count; rush-hour raises a sustained bus
//! saturation incident at the default span while steady-hd raises none;
//! and disabling the hub (`--no-telemetry`) leaves the fleet books and
//! stats digest exactly where they were before telemetry existed.

use rcnet_dla::serve::{
    run_fleet, FleetConfig, IncidentKind, Scenario, TelemetryConfig, PRESET_NAMES,
};
use rcnet_dla::util::json::Json;

fn preset_cfg(name: &str, seed: u64, threads: usize) -> FleetConfig {
    // 2 s spans rush-hour's whole churn window (same choice as
    // tests/scenario_fleet.rs) while keeping the full matrix cheap.
    FleetConfig {
        seconds: 2.0,
        seed,
        threads,
        ..FleetConfig::new(Scenario::preset(name).expect("bundled preset"))
    }
}

/// The headline determinism pin: every bundled preset, two seeds, two
/// parallel thread counts vs the serial reference, plus a repeated
/// serial run — the exported Chrome trace document and the incident
/// list are byte-for-byte identical in all of them.
#[test]
fn every_preset_telemetry_is_byte_identical_across_seeds_and_thread_counts() {
    for name in PRESET_NAMES {
        for seed in [1u64, 7] {
            let serial = run_fleet(&preset_cfg(name, seed, 1)).expect("serial run");
            let tel = serial.telemetry.as_ref().expect("telemetry on by default");
            let doc = tel.to_chrome_json(name).to_string();
            assert!(!tel.windows.is_empty(), "{name} seed {seed}: no windows sampled");

            // Run-to-run: a second serial run reproduces the bytes.
            let again = run_fleet(&preset_cfg(name, seed, 1)).expect("serial rerun");
            let tel2 = again.telemetry.as_ref().expect("telemetry on rerun");
            assert_eq!(doc, tel2.to_chrome_json(name).to_string(), "{name} seed {seed}: rerun");

            // Serial vs parallel at several thread counts.
            for threads in [2usize, 8] {
                let parallel = run_fleet(&preset_cfg(name, seed, threads)).expect("parallel run");
                let ptel = parallel.telemetry.as_ref().expect("telemetry on in parallel");
                assert_eq!(
                    serial.stats_digest(),
                    parallel.stats_digest(),
                    "{name} seed {seed} x{threads}: digest diverged"
                );
                assert_eq!(
                    tel.incidents, ptel.incidents,
                    "{name} seed {seed} x{threads}: incident lists diverged"
                );
                assert_eq!(
                    doc,
                    ptel.to_chrome_json(name).to_string(),
                    "{name} seed {seed} x{threads}: chrome trace diverged"
                );
            }
        }
    }
}

/// The incident acceptance pin at the *default* configuration (the
/// exact run `fleet --scenario rush-hour --telemetry out.json` does):
/// rush-hour's burst overloads the shared bus long enough to raise at
/// least one sustained-saturation incident, while steady-hd — chronic
/// but stable load — raises none.
#[test]
fn rush_hour_saturates_and_steady_hd_does_not() {
    let rush = run_fleet(&FleetConfig::new(Scenario::preset("rush-hour").expect("preset")))
        .expect("rush-hour run");
    let rtel = rush.telemetry.as_ref().expect("telemetry on by default");
    assert!(
        rtel.incidents_of(IncidentKind::SustainedSaturation).count() >= 1,
        "rush-hour burst must raise a sustained-saturation incident; got {:?}",
        rtel.incidents
    );

    let steady = run_fleet(&FleetConfig::new(Scenario::preset("steady-hd").expect("preset")))
        .expect("steady-hd run");
    let stel = steady.telemetry.as_ref().expect("telemetry on by default");
    assert_eq!(
        stel.incidents_of(IncidentKind::SustainedSaturation).count(),
        0,
        "steady-hd load is chronic, not an onset: {:?}",
        stel.incidents
    );
}

/// The `--no-telemetry` fast-path pin: a hub-off run carries no
/// telemetry report, and its stats digest equals the hub-on run with
/// the telemetry section stripped — the hub observes the fleet without
/// perturbing it, and hub-off digests still match pre-telemetry pins.
#[test]
fn disabling_telemetry_leaves_the_fleet_books_untouched() {
    for name in PRESET_NAMES {
        let on = run_fleet(&preset_cfg(name, 1, 1)).expect("hub-on run");
        let off = run_fleet(&FleetConfig {
            telemetry: TelemetryConfig::off(),
            ..preset_cfg(name, 1, 1)
        })
        .expect("hub-off run");
        assert!(off.telemetry.is_none(), "{name}: hub-off run must carry no telemetry");
        assert!(on.telemetry.is_some(), "{name}: default run must carry telemetry");
        assert_ne!(
            on.stats_digest(),
            off.stats_digest(),
            "{name}: telemetry must be folded into the digest when present"
        );
        let mut stripped = on.clone();
        stripped.telemetry = None;
        assert_eq!(
            stripped.stats_digest(),
            off.stats_digest(),
            "{name}: hub must not perturb the fleet books"
        );
    }
}

/// The exported document is a well-formed Chrome trace-event file: it
/// parses, carries `traceEvents` + `displayTimeUnit`, names the
/// scenario in `otherData`, and embeds the window series, incident
/// list, and metrics snapshot alongside.
#[test]
fn chrome_trace_document_is_well_formed() {
    let report = run_fleet(&preset_cfg("rush-hour", 1, 1)).expect("rush-hour run");
    let tel = report.telemetry.as_ref().expect("telemetry on by default");
    let doc = Json::parse(&tel.to_chrome_json("rush-hour").to_string()).expect("doc parses");

    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace must carry events");
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("event phase");
        assert!(
            matches!(ph, "M" | "C" | "X" | "i"),
            "unexpected trace event phase {ph:?}"
        );
    }

    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("scenario").and_then(Json::as_str), Some("rush-hour"));
    assert_eq!(
        other.get("schema").and_then(Json::as_str),
        Some("rcnet-dla/telemetry/v1")
    );
    assert!(other.get("total_ticks").and_then(Json::as_u64).is_some_and(|t| t > 0));

    let series = doc.get("series").and_then(Json::as_arr).expect("series array");
    assert_eq!(series.len(), tel.windows.len(), "one series row per window");
    assert!(doc.get("incidents").and_then(Json::as_arr).is_some(), "incidents array");
    assert!(doc.get("metrics").is_some(), "metrics snapshot");
}
