//! Telemetry acceptance pins: for every bundled preset the telemetry
//! document — Chrome trace JSON, window series, and incident list — is
//! byte-identical across repeated runs and across serial vs parallel
//! engines at every thread count; rush-hour raises a sustained bus
//! saturation incident at the default span while steady-hd raises none;
//! and disabling the hub (`--no-telemetry`) leaves the fleet books and
//! stats digest exactly where they were before telemetry existed.

use rcnet_dla::serve::{
    detect_incidents, run_fleet, ChipSpec, FaultEvent, FaultKind, FleetConfig, IncidentKind,
    ModelId, QosClass, Scenario, StreamScript, StreamSpec, TelemetryConfig, WindowSample,
    PRESET_NAMES, SAT_MIN_WINDOWS, STARVE_WINDOWS, WARMUP_WINDOWS,
};
use rcnet_dla::util::json::Json;

fn preset_cfg(name: &str, seed: u64, threads: usize) -> FleetConfig {
    // 2 s spans rush-hour's whole churn window (same choice as
    // tests/scenario_fleet.rs) while keeping the full matrix cheap.
    FleetConfig {
        seconds: 2.0,
        seed,
        threads,
        ..FleetConfig::new(Scenario::preset(name).expect("bundled preset"))
    }
}

/// The headline determinism pin: every bundled preset, two seeds, two
/// parallel thread counts vs the serial reference, plus a repeated
/// serial run — the exported Chrome trace document and the incident
/// list are byte-for-byte identical in all of them.
#[test]
fn every_preset_telemetry_is_byte_identical_across_seeds_and_thread_counts() {
    for name in PRESET_NAMES {
        for seed in [1u64, 7] {
            let serial = run_fleet(&preset_cfg(name, seed, 1)).expect("serial run");
            let tel = serial.telemetry.as_ref().expect("telemetry on by default");
            let doc = tel.to_chrome_json(name).to_string();
            assert!(!tel.windows.is_empty(), "{name} seed {seed}: no windows sampled");

            // Run-to-run: a second serial run reproduces the bytes.
            let again = run_fleet(&preset_cfg(name, seed, 1)).expect("serial rerun");
            let tel2 = again.telemetry.as_ref().expect("telemetry on rerun");
            assert_eq!(doc, tel2.to_chrome_json(name).to_string(), "{name} seed {seed}: rerun");

            // Serial vs parallel at several thread counts.
            for threads in [2usize, 8] {
                let parallel = run_fleet(&preset_cfg(name, seed, threads)).expect("parallel run");
                let ptel = parallel.telemetry.as_ref().expect("telemetry on in parallel");
                assert_eq!(
                    serial.stats_digest(),
                    parallel.stats_digest(),
                    "{name} seed {seed} x{threads}: digest diverged"
                );
                assert_eq!(
                    tel.incidents, ptel.incidents,
                    "{name} seed {seed} x{threads}: incident lists diverged"
                );
                assert_eq!(
                    doc,
                    ptel.to_chrome_json(name).to_string(),
                    "{name} seed {seed} x{threads}: chrome trace diverged"
                );
            }
        }
    }
}

/// The incident acceptance pin at the *default* configuration (the
/// exact run `fleet --scenario rush-hour --telemetry out.json` does):
/// rush-hour's burst overloads the shared bus long enough to raise at
/// least one sustained-saturation incident, while steady-hd — chronic
/// but stable load — raises none.
#[test]
fn rush_hour_saturates_and_steady_hd_does_not() {
    let rush = run_fleet(&FleetConfig::new(Scenario::preset("rush-hour").expect("preset")))
        .expect("rush-hour run");
    let rtel = rush.telemetry.as_ref().expect("telemetry on by default");
    assert!(
        rtel.incidents_of(IncidentKind::SustainedSaturation).count() >= 1,
        "rush-hour burst must raise a sustained-saturation incident; got {:?}",
        rtel.incidents
    );

    let steady = run_fleet(&FleetConfig::new(Scenario::preset("steady-hd").expect("preset")))
        .expect("steady-hd run");
    let stel = steady.telemetry.as_ref().expect("telemetry on by default");
    assert_eq!(
        stel.incidents_of(IncidentKind::SustainedSaturation).count(),
        0,
        "steady-hd load is chronic, not an onset: {:?}",
        stel.incidents
    );
}

/// The `--no-telemetry` fast-path pin: a hub-off run carries no
/// telemetry report, and its stats digest equals the hub-on run with
/// the telemetry section stripped — the hub observes the fleet without
/// perturbing it, and hub-off digests still match pre-telemetry pins.
#[test]
fn disabling_telemetry_leaves_the_fleet_books_untouched() {
    for name in PRESET_NAMES {
        let on = run_fleet(&preset_cfg(name, 1, 1)).expect("hub-on run");
        let off = run_fleet(&FleetConfig {
            telemetry: TelemetryConfig::off(),
            ..preset_cfg(name, 1, 1)
        })
        .expect("hub-off run");
        assert!(off.telemetry.is_none(), "{name}: hub-off run must carry no telemetry");
        assert!(on.telemetry.is_some(), "{name}: default run must carry telemetry");
        assert_ne!(
            on.stats_digest(),
            off.stats_digest(),
            "{name}: telemetry must be folded into the digest when present"
        );
        let mut stripped = on.clone();
        stripped.telemetry = None;
        assert_eq!(
            stripped.stats_digest(),
            off.stats_digest(),
            "{name}: hub must not perturb the fleet books"
        );
    }
}

/// The exported document is a well-formed Chrome trace-event file: it
/// parses, carries `traceEvents` + `displayTimeUnit`, names the
/// scenario in `otherData`, and embeds the window series, incident
/// list, and metrics snapshot alongside.
#[test]
fn chrome_trace_document_is_well_formed() {
    let report = run_fleet(&preset_cfg("rush-hour", 1, 1)).expect("rush-hour run");
    let tel = report.telemetry.as_ref().expect("telemetry on by default");
    let doc = Json::parse(&tel.to_chrome_json("rush-hour").to_string()).expect("doc parses");

    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace must carry events");
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("event phase");
        assert!(
            matches!(ph, "M" | "C" | "X" | "i"),
            "unexpected trace event phase {ph:?}"
        );
    }

    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("scenario").and_then(Json::as_str), Some("rush-hour"));
    assert_eq!(
        other.get("schema").and_then(Json::as_str),
        Some("rcnet-dla/telemetry/v1")
    );
    assert!(other.get("total_ticks").and_then(Json::as_u64).is_some_and(|t| t > 0));

    let series = doc.get("series").and_then(Json::as_arr).expect("series array");
    assert_eq!(series.len(), tel.windows.len(), "one series row per window");
    assert!(doc.get("incidents").and_then(Json::as_arr).is_some(), "incidents array");
    assert!(doc.get("metrics").is_some(), "metrics snapshot");
}

/// A synthetic window with `sat` of 100 ticks saturated — the unit for
/// driving `detect_incidents` straight at its integer thresholds.
fn sat_win(i: u64, sat: u64) -> WindowSample {
    WindowSample { window: i, ticks: 100, saturated_ticks: sat, ..WindowSample::default() }
}

/// Satellite pin: the saturation detector's thresholds are exact in
/// integers. Entering takes *exactly* half the ticks (49/100 does not),
/// a window at exactly the 1/4 exit threshold does NOT end an episode
/// (exit is strictly below), and one tick fewer does.
#[test]
fn saturation_enter_and_exit_thresholds_are_exact() {
    let sustained = |ws: &[WindowSample]| {
        let (inc, _) = detect_incidents(ws, 100);
        inc.iter().filter(|i| i.kind == IncidentKind::SustainedSaturation).count()
    };
    let quiet_warmup: Vec<WindowSample> =
        (0..WARMUP_WINDOWS as u64).map(|i| sat_win(i, 0)).collect();

    // Exactly 1/2 enters: SAT_MIN_WINDOWS windows at 50/100 sustain.
    let mut ws = quiet_warmup.clone();
    for k in 0..SAT_MIN_WINDOWS as u64 {
        ws.push(sat_win(WARMUP_WINDOWS as u64 + k, 50));
    }
    ws.push(sat_win(ws.len() as u64, 0));
    assert_eq!(sustained(&ws), 1, "50/100 is >= 1/2: the episode must enter");

    // One tick under never enters.
    let mut ws = quiet_warmup.clone();
    for k in 0..SAT_MIN_WINDOWS as u64 {
        ws.push(sat_win(WARMUP_WINDOWS as u64 + k, 49));
    }
    ws.push(sat_win(ws.len() as u64, 0));
    assert_eq!(sustained(&ws), 0, "49/100 is < 1/2: the episode must not enter");

    // Exactly 1/4 does NOT exit: enter at 50, hold at 25 long enough
    // that the episode reaches the minimum length, then drop below.
    let mut ws = quiet_warmup.clone();
    ws.push(sat_win(WARMUP_WINDOWS as u64, 50));
    for k in 1..SAT_MIN_WINDOWS as u64 {
        ws.push(sat_win(WARMUP_WINDOWS as u64 + k, 25));
    }
    ws.push(sat_win(ws.len() as u64, 24));
    assert_eq!(sustained(&ws), 1, "25/100 is not < 1/4: it must hold the episode open");

    // One tick under the exit threshold ends it immediately — each
    // 1-window episode is below the minimum, so nothing is reported.
    let mut ws = quiet_warmup.clone();
    for k in 0..SAT_MIN_WINDOWS as u64 {
        ws.push(sat_win(WARMUP_WINDOWS as u64 + 2 * k, 50));
        ws.push(sat_win(WARMUP_WINDOWS as u64 + 2 * k + 1, 24));
    }
    assert_eq!(sustained(&ws), 0, "24/100 is < 1/4: every episode exits after one window");
}

/// Satellite pin: the warmup boundary is off-by-one-exact. Saturation at
/// the 1/4 exit threshold in the *last* warmup window marks the load
/// chronic (no onset is ever reported); one tick below it does not, and
/// an episode starting in the first post-warmup window is reported.
#[test]
fn warmup_chronic_marking_is_exact_at_the_boundary() {
    let sustained = |ws: &[WindowSample]| {
        let (inc, _) = detect_incidents(ws, 100);
        inc.iter().filter(|i| i.kind == IncidentKind::SustainedSaturation).count()
    };
    let episode = |warm_sat: u64| {
        let mut ws: Vec<WindowSample> = (0..WARMUP_WINDOWS as u64 - 1)
            .map(|i| sat_win(i, 0))
            .collect();
        ws.push(sat_win(WARMUP_WINDOWS as u64 - 1, warm_sat));
        for k in 0..SAT_MIN_WINDOWS as u64 {
            ws.push(sat_win(WARMUP_WINDOWS as u64 + k, 100));
        }
        ws.push(sat_win(ws.len() as u64, 0));
        ws
    };
    assert_eq!(
        sustained(&episode(25)),
        0,
        "25/100 in the last warmup window is chronic: no onset"
    );
    assert_eq!(
        sustained(&episode(24)),
        1,
        "24/100 in warmup is clean: the first post-warmup window starts an onset"
    );
}

/// Satellite pin, end to end: a chip pool that is down for the whole run
/// starves its streams — frames release and shed, nothing completes, the
/// starving-stream incident fires — while every reported statistic stays
/// zero, not NaN. A whole-run outage reports no chip-outage incident
/// (onset semantics: the chip was never seen up).
#[test]
fn whole_run_chip_down_starves_streams_with_finite_stats() {
    let scenario = Scenario {
        name: "blackout".into(),
        chips: vec![ChipSpec::paper()],
        streams: vec![StreamScript::steady(
            StreamSpec { hw: (720, 1280), target_fps: 30.0, qos: QosClass::Gold },
            ModelId::Deployed,
        )],
        faults: vec![FaultEvent {
            chip: 0,
            start_ms: 0.0,
            end_ms: 10_000.0,
            kind: FaultKind::ChipDown,
        }],
        standby: Vec::new(),
    };
    let cfg = FleetConfig { seconds: 1.0, ..FleetConfig::new(scenario) };
    let r = run_fleet(&cfg).expect("blackout run");

    let s = &r.per_stream[0];
    assert!(s.admitted, "admission is capability-based, not liveness-based");
    assert!(s.released > 0, "the stream keeps releasing into the outage");
    assert_eq!(r.completed(), 0, "a downed pool completes nothing");
    assert_eq!(s.p50_ms(), 0.0, "zero, not NaN");
    assert_eq!(s.p99_ms(), 0.0, "zero, not NaN");
    assert!(s.miss_rate().is_finite() && s.miss_rate() == 0.0);
    assert!(s.shed_rate().is_finite() && s.shed_rate() > 0.0);
    assert!(r.miss_rate().is_finite());
    assert!(r.loss_rate().is_finite());

    let tel = r.telemetry.as_ref().expect("telemetry on by default");
    let starving: Vec<_> = tel.incidents_of(IncidentKind::StarvingStream).collect();
    assert!(!starving.is_empty(), "a whole-run outage starves the stream");
    assert!(starving.iter().all(|i| i.stream == Some(0)));
    assert!(
        starving.iter().all(|i| (i.last_window - i.first_window) as usize + 1 >= STARVE_WINDOWS),
        "starvation runs meet the minimum window count"
    );
    assert_eq!(
        tel.incidents_of(IncidentKind::ChipOutage).count(),
        0,
        "a chip down from its first window is a steady state, not an outage onset"
    );
}

/// Mid-run outages DO report: chip-failure's scripted 0.6-1.4 s death of
/// chip 1 is exactly eight full windows down after having been seen up,
/// and the other two (derated, not down) chips report nothing.
#[test]
fn chip_failure_preset_reports_the_mid_run_outage() {
    let r = run_fleet(&preset_cfg("chip-failure", 1, 1)).expect("chip-failure run");
    let tel = r.telemetry.as_ref().expect("telemetry on by default");
    let outages: Vec<_> = tel.incidents_of(IncidentKind::ChipOutage).collect();
    assert_eq!(outages.len(), 1, "exactly one chip died: {:?}", tel.incidents);
    let o = outages[0];
    assert_eq!(o.chip, Some(1), "chip 1 is the one scripted down");
    assert_eq!(o.magnitude_ppm, 800, "0.6 s to 1.4 s is 800 down ticks");
    assert_eq!(o.first_window, 6);
    assert_eq!(o.last_window, 13);
}
