//! Event-engine acceptance pins: the discrete-event fleet engine
//! (`FleetSim::run_event`, selected with `Engine::Event`) must be a
//! byte-identical replacement for the serial per-tick oracle — stats
//! digest, report text, JSON document and exported Chrome trace — on
//! every bundled preset across seeds, on sampled fleets spanning
//! under- and over-load, on churn-heavy custom scenarios whose idle
//! gaps force far-calendar scheduling and multi-tick jumps, and on a
//! reduced slice of the metro-scale preset the event engine exists to
//! serve. Reruns of the event engine itself must also be stable.

use rcnet_dla::serve::{
    run_fleet, AdmissionPolicy, ChipSpec, Engine, FleetConfig, FleetReport, ModelId, QosClass,
    Scenario, StreamScript, StreamSpec, PRESET_NAMES,
};

fn preset_cfg(name: &str, seed: u64, engine: Engine) -> FleetConfig {
    // 2 s spans rush-hour's whole churn window (same choice as
    // tests/scenario_fleet.rs), so arrivals, departures, faults and
    // QoS downshifts all fire mid-run under both engines.
    FleetConfig {
        seconds: 2.0,
        seed,
        engine,
        ..FleetConfig::new(Scenario::preset(name).expect("bundled preset"))
    }
}

/// Byte-identity oracle shared with `tests/scenario_fleet.rs`: digest
/// plus both human-facing documents.
fn assert_identical(a: &FleetReport, b: &FleetReport, what: &str) {
    assert_eq!(a.stats_digest(), b.stats_digest(), "stats digest diverged: {what}");
    assert_eq!(a.to_string(), b.to_string(), "report text diverged: {what}");
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "json document diverged: {what}"
    );
}

/// The headline pin: every bundled preset, two seeds — the event
/// engine's report AND its exported Chrome trace byte-match the serial
/// reference, and an event-engine rerun reproduces its own bytes.
#[test]
fn every_preset_is_byte_identical_event_vs_serial() {
    for name in PRESET_NAMES {
        for seed in [1u64, 7] {
            let serial = run_fleet(&preset_cfg(name, seed, Engine::Tick)).expect("serial run");
            assert!(serial.released() > 0, "{name} seed {seed} released nothing");
            let event = run_fleet(&preset_cfg(name, seed, Engine::Event)).expect("event run");
            assert_identical(&serial, &event, &format!("{name}, seed {seed}, event engine"));

            let stel = serial.telemetry.as_ref().expect("telemetry on by default");
            let etel = event.telemetry.as_ref().expect("telemetry on in event engine");
            assert_eq!(
                stel.incidents, etel.incidents,
                "{name} seed {seed}: incident lists diverged"
            );
            assert_eq!(
                stel.to_chrome_json(name).to_string(),
                etel.to_chrome_json(name).to_string(),
                "{name} seed {seed}: chrome trace diverged"
            );

            let again = run_fleet(&preset_cfg(name, seed, Engine::Event)).expect("event rerun");
            assert_eq!(
                event.to_json().to_string(),
                again.to_json().to_string(),
                "{name} seed {seed}: event rerun json diverged"
            );
        }
    }
}

/// Property sweep over sampled fleets: stream counts from trivially
/// idle to heavily oversubscribed, several seeds, and both admission
/// policies. Overload engages expiry, overflow shedding and dispatch
/// backpressure — the phases where the event engine's heap order must
/// reproduce the serial ready-queue scan exactly.
#[test]
fn sampled_fleets_are_identical_across_load_levels() {
    for &(streams, chips) in &[(1usize, 1usize), (6, 2), (24, 4), (64, 8)] {
        for seed in [1u64, 5, 11] {
            for policy in [
                AdmissionPolicy::AdmitAll,
                AdmissionPolicy::DemandLimit { oversub: 2.0 },
            ] {
                let base = FleetConfig {
                    seconds: 1.0,
                    admission: policy,
                    ..FleetConfig::sampled(streams, chips, seed)
                };
                let serial = run_fleet(&base).expect("serial run");
                let event = run_fleet(&FleetConfig { engine: Engine::Event, ..base.clone() })
                    .expect("event run");
                assert_identical(
                    &serial,
                    &event,
                    &format!("sampled {streams}x{chips} seed {seed} {policy:?}"),
                );
            }
        }
    }
}

/// Far-calendar and jump coverage: streams whose release periods are
/// far longer than the 256-slot near ring (1-4 fps at a 1 ms tick),
/// plus churn that arrives and departs deep inside the run. Between
/// releases the fleet is provably inert, so the event engine jumps
/// hundreds of ticks at a time — and must still land on the serial
/// bytes, QoS-window and telemetry-window edges included.
#[test]
fn sparse_streams_with_far_gaps_match_through_idle_jumps() {
    let spec = |fps: f64| StreamSpec {
        hw: (416, 416),
        target_fps: fps,
        qos: QosClass::Silver,
    };
    let scenario = Scenario {
        name: "sparse-far".into(),
        chips: vec![ChipSpec::paper(); 2],
        streams: vec![
            StreamScript::steady(spec(1.0), ModelId::Deployed),
            StreamScript::steady(spec(2.0), ModelId::Deployed),
            // Arrives late and leaves: both edges land mid-jump range.
            StreamScript {
                spec: spec(4.0),
                model: ModelId::Deployed,
                arrival_ms: 777.0,
                departure_ms: Some(2_111.0),
            },
            // Arrives 1 ms before the end of a 3 s run: the wheel entry
            // seeds but the run ends before anything completes.
            StreamScript {
                spec: spec(1.0),
                model: ModelId::Deployed,
                arrival_ms: 2_999.0,
                departure_ms: None,
            },
        ],
        faults: Vec::new(),
        standby: Vec::new(),
    };
    let base = FleetConfig { seconds: 3.0, ..FleetConfig::new(scenario) };
    let serial = run_fleet(&base).expect("serial run");
    let event =
        run_fleet(&FleetConfig { engine: Engine::Event, ..base }).expect("event run");
    assert_identical(&serial, &event, "sparse far-gap scenario");
    assert!(serial.released() > 0, "the sparse streams still release frames");
}

/// Contention identity: a pool too small for its gold-heavy demand, so
/// every tick mixes dispatch backpressure, deadline expiry and
/// overflow shedding. There are no idle spans to jump — this pins the
/// hot-path replay alone.
#[test]
fn saturated_pool_is_identical_with_no_idle_spans() {
    let mut streams = Vec::new();
    for i in 0..12 {
        streams.push(StreamScript::steady(
            StreamSpec {
                hw: if i % 3 == 0 { (720, 1280) } else { (416, 416) },
                target_fps: 30.0,
                qos: if i % 2 == 0 { QosClass::Gold } else { QosClass::Bronze },
            },
            ModelId::Deployed,
        ));
    }
    let scenario = Scenario {
        name: "saturated".into(),
        chips: vec![ChipSpec::edge(); 2],
        streams,
        faults: Vec::new(),
        standby: Vec::new(),
    };
    let base = FleetConfig {
        seconds: 1.0,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::new(scenario)
    };
    let serial = run_fleet(&base).expect("serial run");
    let event =
        run_fleet(&FleetConfig { engine: Engine::Event, ..base }).expect("event run");
    assert_identical(&serial, &event, "saturated pool");
    let shed: u64 = serial.per_stream.iter().map(|s| s.shed).sum();
    assert!(shed > 0, "the scenario must actually shed to exercise those phases");
}

/// The metro slice: a reduced span of the 100k-stream preset the event
/// engine was built for. The full-span run lives in the bench family
/// (`BENCH_metro.json`); here a 0.3 s slice pins digest, books and the
/// Chrome trace against the serial oracle inside the test suite.
#[test]
fn metro_slice_matches_the_serial_oracle() {
    let base = FleetConfig {
        seconds: 0.3,
        ..FleetConfig::new(Scenario::preset("metro").expect("metro preset"))
    };
    let serial = run_fleet(&base).expect("serial metro slice");
    let event = run_fleet(&FleetConfig { engine: Engine::Event, ..base })
        .expect("event metro slice");
    assert_eq!(
        serial.stats_digest(),
        event.stats_digest(),
        "metro slice: digest diverged"
    );
    assert_eq!(serial.released(), event.released(), "metro slice: releases diverged");
    assert_eq!(serial.rejected, event.rejected, "metro slice: admission diverged");
    let stel = serial.telemetry.as_ref().expect("telemetry on by default");
    let etel = event.telemetry.as_ref().expect("telemetry on in event engine");
    assert_eq!(
        stel.to_chrome_json("metro").to_string(),
        etel.to_chrome_json("metro").to_string(),
        "metro slice: chrome trace diverged"
    );
    assert!(serial.released() > 0, "the slice does real work");
    assert!(
        serial.per_stream.len() > 100_000,
        "metro really is metro-scale ({} streams)",
        serial.per_stream.len()
    );
}

/// The engine knob round-trips through the builder and `Engine::parse`
/// exactly as the CLI uses it.
#[test]
fn engine_knob_round_trips() {
    assert_eq!(Engine::parse("tick"), Some(Engine::Tick));
    assert_eq!(Engine::parse("event"), Some(Engine::Event));
    assert_eq!(Engine::parse("warp"), None);
    assert_eq!(Engine::Event.name(), "event");
    let cfg = rcnet_dla::serve::FleetConfigBuilder::new(Scenario::sampled(4, 2, 1))
        .engine(Engine::Event)
        .build()
        .expect("builder accepts the engine knob");
    assert_eq!(cfg.engine, Engine::Event);
}
