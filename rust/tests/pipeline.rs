//! Multi-chip pipeline acceptance pins: the `pipeline-giant` preset
//! admits the untileable DeepLabv3@1080p onto a two-chip datacenter
//! pair and completes frames, byte-identical across engines, seeds and
//! thread counts; every zoo model that admits a 2-way split prices its
//! inter-chip hand-off byte-for-byte to [`TrafficModel::handoff_bytes`];
//! single-chip placements leave the existing presets' reports
//! structurally pipeline-free; and the typed [`FleetConfigBuilder`]
//! reproduces the legacy constructors exactly while rejecting configs
//! the engines would reject at run time.

use rcnet_dla::config::ChipConfig;
use rcnet_dla::fusion::FusionConfig;
use rcnet_dla::model::zoo::{plan_fixtures, PAPER_RESOLUTIONS};
use rcnet_dla::plan::{split_pipeline, Planner};
use rcnet_dla::serve::prelude::*;
use rcnet_dla::traffic::TrafficModel;

/// The giant's frames take ~2 virtual seconds end to end across the two
/// stages; 6 s completes several and keeps the companion stream busy.
fn giant_cfg(seed: u64, threads: usize) -> FleetConfig {
    FleetConfigBuilder::new(Scenario::preset("pipeline-giant").expect("bundled preset"))
        .seconds(6.0)
        .seed(seed)
        .threads(threads)
        .build()
        .expect("valid config")
}

/// Every zoo model that admits a 2-way split prices the hand-off
/// byte-for-byte to the analytic traffic model — the same accounting the
/// fused schedule charges for cross-boundary reads.
#[test]
fn zoo_two_way_splits_pin_handoff_to_the_traffic_model() {
    let chip = ChipConfig::paper_chip();
    let tm = TrafficModel::new(chip);
    let cfg = FusionConfig::paper_default();
    let mut splits = 0usize;
    for fx in plan_fixtures() {
        let net = (fx.build)();
        for &hw in &PAPER_RESOLUTIONS {
            let groups = Planner::OptimalDp.plan(&net, &cfg, &chip, hw).groups;
            let Some(plan) = split_pipeline(&net, &groups, hw, &chip, 2) else {
                continue;
            };
            splits += 1;
            assert_eq!(plan.stages.len(), 2, "{} at {hw:?}", fx.name);
            assert_eq!(plan.stages[0].handoff_in_bytes, 0);
            let cut = plan.stages[1].group_start;
            assert_eq!(
                plan.handoff_bytes,
                tm.handoff_bytes(&net, &groups, cut, hw),
                "{} at {hw:?}: hand-off bytes must match the traffic model",
                fx.name
            );
            assert_eq!(plan.stages[1].handoff_in_bytes, plan.handoff_bytes);
        }
    }
    assert!(splits >= 6, "every zoo model splits somewhere; saw only {splits}");
}

/// The headline acceptance pin: the untileable giant is admitted onto
/// an ordered two-chip placement, completes frames end to end, and its
/// per-frame hand-off bill in the report equals the split plan's price.
#[test]
fn pipeline_giant_serves_the_untileable_giant_end_to_end() {
    let r = run_fleet(&giant_cfg(1, 1)).expect("pipeline-giant run");

    let giant = &r.per_stream[0];
    assert!(giant.admitted, "the giant is admitted via the 2-chip placement");
    let p = giant.pipeline.as_ref().expect("the giant is pipeline-served");
    assert_eq!(p.stages, 2);
    assert_eq!(p.chips.len(), 2, "an ordered chip set of two stages");
    assert_ne!(p.chips[0], p.chips[1], "stages land on distinct chips");
    assert!(giant.completed() > 0, "the giant completes frames end to end");
    assert!(
        p.handoffs >= giant.completed(),
        "every completed frame crossed the cut: {} hand-offs, {} completions",
        p.handoffs,
        giant.completed()
    );

    // The report's per-frame hand-off bill is the split plan's price,
    // recomputed from scratch at the preset's own operating point.
    let scenario = Scenario::preset("pipeline-giant").expect("bundled preset");
    let chip = scenario.reference_chip();
    let (net, fusion_cfg) = ModelId::Zoo("deeplabv3").build().expect("giant builds");
    let groups = Planner::OptimalDp.plan(&net, &fusion_cfg, &chip, (1080, 1920)).groups;
    let plan =
        split_pipeline(&net, &groups, (1080, 1920), &chip, 2).expect("the giant 2-way splits");
    assert_eq!(p.handoff_bytes_per_frame, plan.handoff_bytes);
    assert!(plan.handoff_bytes > 0);

    // The 416p companion rides a single chip, exactly as before.
    let small = &r.per_stream[1];
    assert!(small.admitted && small.pipeline.is_none());
    assert!(small.completed() > 0, "the companion stream is served normally");

    // Telemetry: hand-offs are counted and the stage spans carry the
    // hand-off bytes in the Chrome export.
    let tel = r.telemetry.as_ref().expect("telemetry on by default");
    let handoffs: u64 =
        r.per_stream.iter().filter_map(|s| s.pipeline.as_ref()).map(|q| q.handoffs).sum();
    assert!(handoffs > 0);
    assert_eq!(tel.hub.counter("fleet.handoffs"), handoffs);
    assert_eq!(
        tel.hub.counter("fleet.handoff_bytes"),
        handoffs * p.handoff_bytes_per_frame
    );
    let doc = tel.to_chrome_json("pipeline-giant").to_string();
    assert!(doc.contains("\"handoff_bytes\""), "stage spans carry the hand-off bytes");
}

/// Serial and parallel engines agree byte-for-byte on the pipeline
/// preset across seeds and thread counts — with frames actually
/// completing (the 2 s all-preset matrix in `scenario_fleet.rs` is too
/// short for the giant to finish).
#[test]
fn pipeline_giant_is_byte_identical_across_seeds_and_thread_counts() {
    for seed in [1u64, 7] {
        let serial = run_fleet(&giant_cfg(seed, 1)).expect("serial run");
        assert!(serial.completed() > 0, "seed {seed}: frames complete");
        for threads in [2usize, 3, 8] {
            let parallel = run_fleet(&giant_cfg(seed, threads)).expect("parallel run");
            assert_eq!(
                serial.stats_digest(),
                parallel.stats_digest(),
                "stats digest diverged: seed {seed}, {threads} threads"
            );
            assert_eq!(
                serial.to_json().to_string(),
                parallel.to_json().to_string(),
                "json document diverged: seed {seed}, {threads} threads"
            );
        }
    }
}

/// Single-chip placements leave the pre-pipeline presets untouched:
/// no stream carries a pipeline record, the report JSON has no
/// `pipeline` key, and the hand-off counters stay unregistered (the
/// lazy-registration pin that keeps their stats digests at the
/// pre-pipeline values).
#[test]
fn single_chip_presets_stay_pipeline_free() {
    for &name in PRESET_NAMES.iter().filter(|&&n| n != "pipeline-giant") {
        let cfg = FleetConfigBuilder::new(Scenario::preset(name).expect("bundled preset"))
            .seconds(1.0)
            .build()
            .expect("valid config");
        let r = run_fleet(&cfg).expect("preset run");
        for s in &r.per_stream {
            assert!(s.pipeline.is_none(), "{name}: single-chip streams carry no pipeline");
        }
        let doc = r.to_json().to_string();
        assert!(!doc.contains("\"pipeline\""), "{name}: report JSON stays pipeline-free");
        let tel = r.telemetry.as_ref().expect("telemetry on by default");
        assert_eq!(tel.hub.counter("fleet.handoffs"), 0);
        assert!(
            tel.hub.iter().all(|(n, _)| !n.contains("handoff")),
            "{name}: hand-off counters register lazily, never on single-chip runs"
        );
    }
}

/// The typed builder is the one construction path: the legacy
/// constructors reproduce it field-for-field, and `build()` rejects
/// everything `run_fleet` would reject.
#[test]
fn builder_matches_legacy_constructors_and_validates() {
    let s = Scenario::preset("steady-hd").expect("bundled preset");
    assert_eq!(
        FleetConfig::new(s.clone()),
        FleetConfigBuilder::new(s.clone()).build().expect("defaults validate")
    );
    assert_eq!(
        FleetConfig::sampled(8, 4, 9),
        FleetConfigBuilder::new(Scenario::sampled(8, 4, 9))
            .seed(9)
            .build()
            .expect("sampled validates")
    );
    assert!(FleetConfigBuilder::new(s.clone()).seconds(0.0).build().is_err());
    assert!(FleetConfigBuilder::new(s.clone()).tick_ms(f64::NAN).build().is_err());
    assert!(FleetConfigBuilder::new(s.clone()).queue_depth(0).build().is_err());
    assert!(FleetConfigBuilder::new(s).bus_mbps(-1.0).build().is_err());
}
