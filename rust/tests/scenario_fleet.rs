//! Scenario acceptance pins: for every bundled preset — stream churn,
//! per-stream models, heterogeneous chip pools, scripted chip faults
//! and QoS degradation under load — the serial and parallel engines
//! produce byte-identical reports across seeds and thread counts;
//! mixed-model scenarios price every stream from its own network's
//! optimal-DP plan (witnessed by per-stream cost provenance); churned
//! streams' statistics window over their actual lifetimes; and the
//! fault presets keep the frame books balanced (requeued, never lost)
//! while billing degraded time only where load actually forces it.

use rcnet_dla::config::ChipConfig;
use rcnet_dla::plan::Planner;
use rcnet_dla::serve::{
    run_fleet, ChipSpec, FleetConfig, FleetReport, ModelId, QosClass, Scenario, StreamScript,
    StreamSpec, PRESET_NAMES,
};

fn preset_cfg(name: &str, seed: u64, threads: usize) -> FleetConfig {
    // 2 s spans rush-hour's whole churn window: every burst arrival
    // (0.5-1.5 s) and the first departures (from 1.9 s) fire mid-run.
    FleetConfig {
        seconds: 2.0,
        seed,
        threads,
        ..FleetConfig::new(Scenario::preset(name).expect("bundled preset"))
    }
}

/// Byte-identity oracle shared with `tests/parallel_fleet.rs`: digest
/// plus the human-facing text.
fn assert_identical(a: &FleetReport, b: &FleetReport, what: &str) {
    assert_eq!(a.stats_digest(), b.stats_digest(), "stats digest diverged: {what}");
    assert_eq!(a.to_string(), b.to_string(), "report text diverged: {what}");
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "json document diverged: {what}"
    );
}

/// The headline acceptance pin: every bundled preset, >= 2 seeds,
/// >= 3 parallel thread counts vs the serial reference — byte-identical,
/// with churn firing mid-run.
#[test]
fn every_preset_is_byte_identical_across_seeds_and_thread_counts() {
    for name in PRESET_NAMES {
        for seed in [1u64, 7] {
            let serial = run_fleet(&preset_cfg(name, seed, 1)).expect("serial run");
            assert!(serial.released() > 0, "{name} seed {seed} released nothing");
            for threads in [2usize, 3, 8] {
                let parallel =
                    run_fleet(&preset_cfg(name, seed, threads)).expect("parallel run");
                assert_identical(
                    &serial,
                    &parallel,
                    &format!("{name}, seed {seed}, {threads} threads"),
                );
            }
        }
    }
}

/// Churn actually happens mid-run and the books reflect it: rush-hour's
/// burst streams arrive late, and its departing streams close with a
/// lifetime shorter than the simulated span.
#[test]
fn rush_hour_churns_mid_run() {
    let r = run_fleet(&preset_cfg("rush-hour", 1, 1)).expect("rush-hour run");
    let late_admitted = r
        .per_stream
        .iter()
        .filter(|s| s.arrival_ms > 0.0 && s.admitted)
        .count();
    assert!(late_admitted > 0, "some burst arrivals must be admitted online");
    let departed: Vec<&rcnet_dla::serve::StreamStats> = r
        .per_stream
        .iter()
        .filter(|s| s.admitted && s.departure_ms.is_some_and(|d| d < r.wall_s * 1e3))
        .collect();
    assert!(!departed.is_empty(), "departures must fire inside the 2 s span");
    for s in &departed {
        assert!(
            s.lifetime_s < r.wall_s,
            "a departed stream's lifetime ({}) must be shorter than the run ({})",
            s.lifetime_s,
            r.wall_s
        );
    }
    // A steady stream's lifetime is the whole span.
    let steady = r
        .per_stream
        .iter()
        .find(|s| s.admitted && s.arrival_ms == 0.0 && s.departure_ms.is_none())
        .expect("rush-hour has steady base streams");
    assert!((steady.lifetime_s - r.wall_s).abs() < 1e-9);
}

/// The mixed-model acceptance pin: every stream in `mixed-zoo` is priced
/// from its *own* network's optimal-DP plan. The report's per-stream
/// cost provenance carries the network hash and the plan shape; both
/// must match a plan recomputed directly from the stream's model.
#[test]
fn mixed_zoo_prices_each_stream_from_its_own_network() {
    let r = run_fleet(&preset_cfg("mixed-zoo", 1, 1)).expect("mixed-zoo run");

    // Four distinct networks were priced.
    let mut hashes: Vec<u64> = r.per_stream.iter().map(|s| s.provenance.net_hash).collect();
    hashes.sort_unstable();
    hashes.dedup();
    assert!(hashes.len() >= 4, "expected >= 4 distinct priced networks, got {hashes:?}");

    let chip = ChipConfig::paper_chip();
    let mut checked: Vec<(ModelId, (u32, u32))> = Vec::new();
    for s in &r.per_stream {
        assert_eq!(s.provenance.planner, Planner::OptimalDp);
        assert!(s.provenance.groups > 0, "a priced plan has at least one group");
        let point = (s.provenance.model, s.spec.hw);
        if checked.contains(&point) {
            continue; // one replan per distinct operating point
        }
        checked.push(point);
        // Recompute the stream's plan from scratch from its own model:
        // hash, group count and feature bytes must all agree.
        let (net, fusion_cfg) = s.provenance.model.build().expect("model builds");
        assert_eq!(s.provenance.net_hash, net.structural_hash(), "hash provenance");
        let plan = Planner::OptimalDp.plan(&net, &fusion_cfg, &chip, s.spec.hw);
        assert_eq!(
            s.provenance.feat_bytes, plan.feat_bytes,
            "{}: provenance must carry its own network's optimal-DP price",
            s.provenance.model.name()
        );
        assert_eq!(s.provenance.groups, plan.groups.len() as u64);
    }
    assert!(checked.len() >= 4, "mixed-zoo spans >= 4 operating points");

    // And the models are genuinely different operating points: the
    // deployed 720p streams must not share a cost with the 416 zoo ones.
    let rc = r
        .per_stream
        .iter()
        .find(|s| s.provenance.model == ModelId::Deployed)
        .expect("mixed-zoo has deployed streams");
    let zoo = r
        .per_stream
        .iter()
        .find(|s| s.provenance.model != ModelId::Deployed)
        .expect("mixed-zoo has zoo streams");
    assert_ne!(rc.cost, zoo.cost, "distinct models must price distinct frame costs");
}

/// Heterogeneous-pool pin: 1080p streams exceed the edge chips'
/// capability bound yet still get served (by the uncapped chips), while
/// the pool's capped chips carry smaller streams.
#[test]
fn hetero_pool_serves_beyond_edge_capability() {
    let r = run_fleet(&preset_cfg("hetero-pool", 1, 1)).expect("hetero-pool run");
    let hd1080: Vec<_> =
        r.per_stream.iter().filter(|s| s.spec.hw == (1080, 1920)).collect();
    assert!(!hd1080.is_empty(), "preset scripts 1080p streams");
    for s in &hd1080 {
        assert!(s.admitted, "1080p streams are servable by the uncapped chips");
        assert!(
            s.completed() > 0,
            "1080p frames must complete despite the capped edge chips"
        );
    }
}

/// A pool of only capped chips cannot serve an oversized stream: the
/// demand-limit policy rejects it at arrival (capability-aware
/// admission), while smaller streams are admitted normally.
#[test]
fn capability_gap_rejects_unservable_streams() {
    let scenario = Scenario {
        name: "edge-only".into(),
        chips: vec![ChipSpec::edge(); 4],
        streams: vec![
            StreamScript::steady(
                StreamSpec { hw: (1080, 1920), target_fps: 15.0, qos: QosClass::Gold },
                ModelId::Deployed,
            ),
            StreamScript::steady(
                StreamSpec { hw: (416, 416), target_fps: 15.0, qos: QosClass::Silver },
                ModelId::Deployed,
            ),
        ],
        faults: Vec::new(),
        standby: Vec::new(),
    };
    let cfg = FleetConfig { seconds: 1.0, ..FleetConfig::new(scenario) };
    let r = run_fleet(&cfg).expect("edge-only run");
    assert!(!r.per_stream[0].admitted, "no edge chip can serve 1080p");
    assert!(r.per_stream[0].refused, "the unservable stream was refused, not absent");
    assert!(r.per_stream[1].admitted, "416 fits the edge capability");
    assert_eq!(r.rejected, 1);
}

/// Under `AdmitAll` an unservable stream IS admitted — but its frames
/// must be shed at dispatch, never waited on: the servable streams
/// behind it keep completing, and the engines stay byte-identical.
#[test]
fn admit_all_sheds_unservable_frames_without_starving_the_pool() {
    use rcnet_dla::serve::AdmissionPolicy;
    let mut streams = vec![StreamScript::steady(
        // Gold 1080p: wins every EDF tie, so without the dispatch-time
        // shed it would head-of-line block the whole pool.
        StreamSpec { hw: (1080, 1920), target_fps: 30.0, qos: QosClass::Gold },
        ModelId::Deployed,
    )];
    for _ in 0..4 {
        streams.push(StreamScript::steady(
            StreamSpec { hw: (416, 416), target_fps: 15.0, qos: QosClass::Silver },
            ModelId::Deployed,
        ));
    }
    let scenario = Scenario {
        name: "edge-admit-all".into(),
        chips: vec![ChipSpec::edge(); 4],
        streams,
        faults: Vec::new(),
        standby: Vec::new(),
    };
    let cfg = FleetConfig {
        seconds: 1.0,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::new(scenario)
    };
    let serial = run_fleet(&FleetConfig { threads: 1, ..cfg.clone() }).expect("serial");
    let parallel = run_fleet(&FleetConfig { threads: 3, ..cfg }).expect("parallel");
    assert_identical(&serial, &parallel, "admit-all unservable");

    let unservable = &serial.per_stream[0];
    assert!(unservable.admitted, "AdmitAll admits even unservable streams");
    assert!(unservable.released > 0);
    assert_eq!(unservable.completed(), 0, "no chip can execute 1080p here");
    // Every frame is shed (at dispatch or expiry) — up to a couple
    // released in the final ticks may still sit in the ready queue.
    assert!(
        unservable.shed + 2 >= unservable.released,
        "unservable frames must be shed, not accumulated: {} shed of {}",
        unservable.shed,
        unservable.released
    );
    for s in &serial.per_stream[1..] {
        assert!(
            s.completed() > 0,
            "servable streams must not be starved by the unservable gold stream"
        );
    }
}

/// Satellite pin, end to end: a stream that arrives too late to finish
/// anything — and one that departs before its first release — must
/// report clean zero statistics (p50/p99 0.0, finite rates), and the
/// engines must still agree byte-for-byte.
#[test]
fn short_lived_streams_have_clean_empty_stats() {
    let spec = StreamSpec { hw: (416, 416), target_fps: 15.0, qos: QosClass::Silver };
    let scenario = Scenario {
        name: "blink".into(),
        chips: vec![ChipSpec::paper(); 2],
        streams: vec![
            // Steady background so the run does real work.
            StreamScript::steady(spec, ModelId::Deployed),
            // Arrives 1 ms before the end: nothing can complete.
            StreamScript {
                spec,
                model: ModelId::Deployed,
                arrival_ms: 999.0,
                departure_ms: None,
            },
            // Departs 1 ms after arriving: at most one release, likely none.
            StreamScript {
                spec,
                model: ModelId::Deployed,
                arrival_ms: 100.0,
                departure_ms: Some(101.0),
            },
        ],
        faults: Vec::new(),
        standby: Vec::new(),
    };
    let cfg = FleetConfig { seconds: 1.0, ..FleetConfig::new(scenario) };
    let serial = run_fleet(&FleetConfig { threads: 1, ..cfg.clone() }).expect("serial");
    let parallel = run_fleet(&FleetConfig { threads: 3, ..cfg }).expect("parallel");
    assert_identical(&serial, &parallel, "blink scenario");

    for idx in [1usize, 2] {
        let s = &serial.per_stream[idx];
        assert!(s.admitted, "blink stream {idx} is admitted");
        assert_eq!(s.completed(), 0, "blink stream {idx} completes nothing");
        assert_eq!(s.p50_ms(), 0.0);
        assert_eq!(s.p99_ms(), 0.0);
        assert!(s.miss_rate().is_finite() && s.miss_rate() == 0.0);
        assert!(s.shed_rate().is_finite());
        assert!(s.lifetime_s >= 0.0 && s.lifetime_s < 0.01);
    }
    assert!(serial.per_stream[0].completed() > 0, "the steady stream does real work");
}

/// The fault differential harness: the three fault presets — diurnal
/// autoscaling, flash-crowd downshift, scripted chip failures — are
/// byte-identical serial vs parallel for 2 seeds x {2, 3, 8} threads
/// (also covered by the all-preset matrix above, pinned here by name so
/// a preset-list regression cannot silently drop them), and a rerun of
/// the same config reproduces the JSON document byte for byte.
#[test]
fn fault_presets_are_byte_identical_and_rerun_stable() {
    for name in ["diurnal-load", "flash-crowd", "chip-failure"] {
        for seed in [1u64, 7] {
            let serial = run_fleet(&preset_cfg(name, seed, 1)).expect("serial run");
            assert!(serial.released() > 0, "{name} seed {seed} released nothing");
            let again = run_fleet(&preset_cfg(name, seed, 1)).expect("serial rerun");
            assert_eq!(
                serial.to_json().to_string(),
                again.to_json().to_string(),
                "{name} seed {seed}: serial rerun json diverged"
            );
            for threads in [2usize, 3, 8] {
                let parallel =
                    run_fleet(&preset_cfg(name, seed, threads)).expect("parallel run");
                assert_identical(
                    &serial,
                    &parallel,
                    &format!("{name}, seed {seed}, {threads} threads"),
                );
            }
        }
    }
}

/// Chip-failure requeue accounting: faults requeue in-flight frames,
/// they never drop them. Every stream's books balance (completions plus
/// sheds never exceed releases; the remainder is work still in the
/// queues at the end), the release schedule is *identical* to the same
/// scenario with the fault script stripped (faults perturb service, not
/// releases), and real work still completes through the outage window.
#[test]
fn chip_failure_requeues_frames_without_losing_any() {
    let faulted = run_fleet(&preset_cfg("chip-failure", 1, 1)).expect("faulted run");

    let mut clean_scenario = Scenario::preset("chip-failure").expect("bundled preset");
    clean_scenario.faults.clear();
    let clean_cfg = FleetConfig {
        seconds: 2.0,
        seed: 1,
        threads: 1,
        ..FleetConfig::new(clean_scenario)
    };
    let clean = run_fleet(&clean_cfg).expect("fault-free run");

    assert!(faulted.completed() > 0, "the pool keeps serving around the faults");
    assert_eq!(faulted.per_stream.len(), clean.per_stream.len());
    for (f, c) in faulted.per_stream.iter().zip(&clean.per_stream) {
        assert!(
            f.completed() + f.shed <= f.released,
            "stream books overdrawn: {} completed + {} shed > {} released",
            f.completed(),
            f.shed,
            f.released
        );
        assert_eq!(
            f.released, c.released,
            "faults must not change the release schedule, only its service"
        );
    }
    // The fault script visibly bites: the faulted run completes no more
    // than the clean one fleet-wide, and strictly loses ground or sheds
    // more somewhere (a 1.4 s outage window on a 3-chip pool is not free).
    assert!(faulted.completed() <= clean.completed());
    let shed = |r: &FleetReport| r.per_stream.iter().map(|s| s.shed).sum::<u64>();
    assert!(
        faulted.completed() < clean.completed() || shed(&faulted) > shed(&clean),
        "the scripted faults must observably perturb service"
    );
}

/// The degraded-seconds acceptance pins: flash-crowd's overload drives
/// the QoS controller to downshift (a nonzero, whole-window degraded
/// bill), steady-hd never degrades, and diurnal-load's pressure raises
/// standby capacity (chip directives fire) — observable straight from
/// the report and its telemetry.
#[test]
fn degraded_seconds_bill_matches_the_load_shape() {
    let flash = run_fleet(&preset_cfg("flash-crowd", 1, 1)).expect("flash-crowd run");
    assert!(flash.degraded_windows() > 0, "flash-crowd must force downshifts");
    assert!(flash.degraded_s() > 0.0);
    assert_eq!(
        flash.degraded_s(),
        flash.degraded_windows() as f64 * flash.qos_window_ms / 1e3,
        "degraded time is billed in whole controller windows"
    );
    let tel = flash.telemetry.as_ref().expect("telemetry on by default");
    assert!(tel.hub.counter("fleet.downshifts") > 0, "downshift events are recorded");

    let steady = run_fleet(&preset_cfg("steady-hd", 1, 1)).expect("steady-hd run");
    assert_eq!(steady.degraded_windows(), 0, "steady-hd never degrades");
    assert_eq!(steady.degraded_s(), 0.0);

    let diurnal = run_fleet(&preset_cfg("diurnal-load", 1, 1)).expect("diurnal-load run");
    let dtel = diurnal.telemetry.as_ref().expect("telemetry on by default");
    assert!(
        dtel.hub.counter("fleet.chip_directives") > 0,
        "diurnal-load's waves must drive the autoscaler"
    );
}

/// The JSON document is deterministic and carries the digest — the CI
/// byte-diff in unit-test form.
#[test]
fn scenario_json_round_is_deterministic() {
    let a = run_fleet(&preset_cfg("mixed-zoo", 1, 1)).expect("run a");
    let b = run_fleet(&preset_cfg("mixed-zoo", 1, 1)).expect("run b");
    let (ja, jb) = (a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(ja, jb, "same config, same document");
    assert!(ja.contains("\"stats_digest\""));
    assert!(ja.contains("\"model\":\"vgg16-converted\""));
}
