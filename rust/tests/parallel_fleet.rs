//! The parallel engine's identity guarantee: for any seed and any
//! worker count, the sharded multi-threaded fleet engine produces
//! **byte-identical** per-stream statistics (p50/p99, miss/shed, every
//! recorded latency bit) to the serial reference engine. This is the
//! property every future "make the fleet faster" change is held to.
//! (Scenario churn and heterogeneous pools are pinned separately in
//! `tests/scenario_fleet.rs`.)

use rcnet_dla::serve::{
    run_fleet, run_fleet_with, AdmissionPolicy, FleetConfig, FleetReport, QosClass, StreamSpec,
};

fn cfg(seed: u64, threads: usize) -> FleetConfig {
    FleetConfig {
        bus_mbps: 2000.0,
        seconds: 1.0,
        threads,
        ..FleetConfig::sampled(24, 6, seed)
    }
}

/// Byte-identity oracle: the stats digest folds every observable bit;
/// the Display string is the human-facing cross-check.
fn assert_identical(a: &FleetReport, b: &FleetReport, what: &str) {
    assert_eq!(a.stats_digest(), b.stats_digest(), "stats digest diverged: {what}");
    assert_eq!(a.to_string(), b.to_string(), "report text diverged: {what}");
    assert_eq!(a.rejected, b.rejected, "{what}");
    assert!(
        a.bus_utilization.to_bits() == b.bus_utilization.to_bits()
            && a.chip_utilization.to_bits() == b.chip_utilization.to_bits(),
        "utilization bits diverged: {what}"
    );
}

#[test]
fn parallel_is_byte_identical_across_seeds_and_thread_counts() {
    for seed in [1u64, 7, 23] {
        let serial = run_fleet(&cfg(seed, 1)).expect("serial run");
        assert!(serial.released() > 0, "seed {seed} released nothing");
        for threads in [2usize, 4] {
            let parallel = run_fleet(&cfg(seed, threads)).expect("parallel run");
            assert_identical(&serial, &parallel, &format!("seed {seed}, {threads} threads"));
        }
    }
}

#[test]
fn auto_thread_count_is_identical_too() {
    let serial = run_fleet(&cfg(11, 1)).expect("serial run");
    let auto = run_fleet(&cfg(11, 0)).expect("auto-threaded run");
    assert_identical(&serial, &auto, "threads=auto");
}

#[test]
fn more_workers_than_chips_or_streams_is_identical() {
    // Worker count far above both shard dimensions: most workers own an
    // empty shard, which must not perturb the merge order.
    let serial = run_fleet(&cfg(5, 1)).expect("serial run");
    let oversharded = run_fleet(&cfg(5, 64)).expect("oversharded run");
    assert_identical(&serial, &oversharded, "64 workers over 6 chips");
}

#[test]
fn identity_holds_under_contention_and_shedding() {
    // A starved bus forces expiry shedding, queue overflow and deadline
    // misses — the paths where a merge-order bug would first show up.
    let base = FleetConfig {
        bus_mbps: 100.0,
        seconds: 1.5,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::sampled(32, 4, 3)
    };
    let serial = run_fleet(&FleetConfig { threads: 1, ..base.clone() }).expect("serial run");
    assert!(
        serial.shed() > 0 || serial.missed() > 0,
        "workload must actually contend to exercise the shed/miss paths"
    );
    let parallel = run_fleet(&FleetConfig { threads: 3, ..base }).expect("parallel run");
    assert_identical(&serial, &parallel, "contended workload");
}

#[test]
fn identity_holds_when_bursts_saturate_the_bus() {
    // The burst-aware arbiter's hardest regime: admitted demand far above
    // the bus, so the chips' profile-shaped bursts overlap past the
    // per-tick budget and the water-filler is splitting scarcity every
    // tick. The serial/parallel identity must survive it, and the report
    // must actually show burst saturation (averages would hide it).
    let base = FleetConfig {
        bus_mbps: 300.0,
        seconds: 1.5,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::sampled(24, 8, 17)
    };
    let serial = run_fleet(&FleetConfig { threads: 1, ..base.clone() }).expect("serial run");
    assert!(
        serial.bus_saturation > 0.0,
        "a starved bus must show saturated ticks: {}",
        serial.bus_saturation
    );
    assert!(
        serial.bus_peak_demand > 1.0,
        "overlapping bursts must exceed the per-tick budget: {}",
        serial.bus_peak_demand
    );
    let parallel = run_fleet(&FleetConfig { threads: 4, ..base }).expect("parallel run");
    assert_identical(&serial, &parallel, "burst-saturated workload");
}

#[test]
fn identity_holds_for_explicit_uniform_stream_lists() {
    // Same-rate same-QoS streams maximize EDF deadline ties: the pinned
    // (stream id, seq) tie-break is what keeps the engines aligned here.
    let specs =
        vec![StreamSpec { hw: (720, 1280), target_fps: 30.0, qos: QosClass::Silver }; 12];
    let base = FleetConfig {
        bus_mbps: 1500.0,
        seconds: 1.0,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::sampled(1, 4, 9)
    };
    let serial =
        run_fleet_with(&FleetConfig { threads: 1, ..base.clone() }, &specs).expect("serial run");
    let parallel =
        run_fleet_with(&FleetConfig { threads: 4, ..base }, &specs).expect("parallel run");
    assert_identical(&serial, &parallel, "uniform tie-heavy stream list");
}
