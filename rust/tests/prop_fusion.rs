//! Property tests over the fusion engine: random fusion-ready networks,
//! random buffer sizes, random prune sequences — the partition/pruning
//! invariants must hold for all of them. (Hand-rolled generator loop: the
//! offline vendor set has no proptest; `Rng` provides the determinism.)

use rcnet_dla::fusion::{
    naive_partition, partition, pruning, rcnet, validate_groups, FusionConfig,
    GammaSet, RcnetOptions, Violation,
};
use rcnet_dla::model::{Act, Layer, Network, SpanKind};
use rcnet_dla::util::{kb, Rng};

/// Random fusion-ready network: conv stem + stages of dw/pw blocks with
/// optional residuals and pools.
fn random_network(rng: &mut Rng) -> Network {
    let mut n = Network::new("rand", (128, 128), 3);
    let c0 = 8 + 8 * rng.range(0, 4);
    n.push(Layer::conv("stem", 3, c0, 3, 1, Act::Relu6));
    let mut c = c0;
    let stages = 2 + rng.range(0, 3);
    for s in 0..stages {
        let blocks = 1 + rng.range(0, 3);
        for b in 0..blocks {
            let c_out = 8 + 8 * rng.range(0, 40);
            let a = n.push(Layer::dw(&format!("s{s}b{b}d"), c, 1, Act::Relu6));
            let z = n.push(Layer::pw(&format!("s{s}b{b}p"), c, c_out, Act::None));
            if c == c_out && rng.f64() < 0.5 {
                n.add_span(SpanKind::Residual, a, z);
            }
            c = c_out;
        }
        if rng.f64() < 0.8 {
            n.push(Layer::maxpool(&format!("s{s}pool"), c, 2, 2));
        }
    }
    n.push(Layer::head("head", c, 40, 1));
    n
}

#[test]
fn partition_invariants_hold_for_random_networks() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..60 {
        let net = random_network(&mut rng);
        assert!(net.check_consistency().is_empty(), "case {case}");
        let cfg = FusionConfig::paper_default().with_buffer(kb(32 + 32 * rng.range(0, 6) as u64));
        for groups in [partition(&net, &cfg), naive_partition(&net, &cfg)] {
            // Exact tiling of the layer list.
            let mut expect = 0;
            for g in &groups {
                assert_eq!(g.start, expect, "case {case}: gap/overlap");
                assert!(g.end >= g.start);
                expect = g.end + 1;
            }
            assert_eq!(expect, net.layers.len(), "case {case}: uncovered tail");
            // Residual atomicity.
            let v = validate_groups(&net, &groups, &cfg);
            assert!(
                v.iter().all(|x| matches!(x, Violation::OverBudget { .. })),
                "case {case}: {v:?}"
            );
        }
    }
}

#[test]
fn random_prune_sequences_preserve_consistency() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..30 {
        let mut net = random_network(&mut rng);
        let mut gammas = GammaSet::synthetic(&net, case);
        for _ in 0..100 {
            let i = rng.range(0, net.layers.len() as u32) as usize;
            if !pruning::prunable(&net, i, 4) {
                continue;
            }
            let ch = (rng.range(0, net.layers[i].c_out) as usize)
                .min(gammas.per_layer[i].len().saturating_sub(1));
            pruning::prune_output_channel(&mut net, &mut gammas, i, ch);
            let errs = net.check_consistency();
            assert!(errs.is_empty(), "case {case}: {errs:?}");
            assert!(gammas.check(&net), "case {case}: gamma desync");
        }
    }
}

#[test]
fn rcnet_always_fits_deployment_groups() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..15 {
        let net = random_network(&mut rng);
        let buf = kb(48 + 16 * rng.range(0, 8) as u64);
        let cfg = FusionConfig::paper_default().with_buffer(buf);
        let gammas = GammaSet::synthetic(&net, case);
        let out = rcnet(&net, &gammas, &cfg, &RcnetOptions::default());
        assert!(out.network.check_consistency().is_empty(), "case {case}");
        for (gi, g) in out.groups.iter().enumerate() {
            let w = g.weight_bytes(&out.network, cfg.precision);
            // A single layer may exceed any buffer (degenerate layer-by-
            // layer group, as the paper allows); multi-layer groups must
            // fit strictly.
            if g.len() > 1 {
                assert!(w <= buf, "case {case} group {gi}: {w} > {buf}");
            }
        }
    }
}

#[test]
fn fused_traffic_never_exceeds_layerwise_features() {
    use rcnet_dla::traffic::TrafficModel;
    let mut rng = Rng::new(0xABCD);
    for case in 0..20 {
        let net = random_network(&mut rng);
        let cfg = FusionConfig::paper_default();
        let gammas = GammaSet::synthetic(&net, case);
        let out = rcnet(&net, &gammas, &cfg, &RcnetOptions::default());
        let tm = TrafficModel::paper_chip();
        let lbl = tm.layer_by_layer(&out.network, (128, 128));
        let fus = tm.fused(&out.network, &out.groups, (128, 128));
        assert!(
            fus.feat_bytes() <= lbl.feat_bytes(),
            "case {case}: fused {} > lbl {}",
            fus.feat_bytes(),
            lbl.feat_bytes()
        );
        assert_eq!(fus.weight_bytes(), lbl.weight_bytes(), "case {case}");
    }
}

#[test]
fn tile_plans_respect_buffer_for_random_networks() {
    use rcnet_dla::config::ChipConfig;
    use rcnet_dla::tile::plan_network;
    let mut rng = Rng::new(0x7117);
    let chip = ChipConfig::paper_chip();
    for case in 0..20 {
        let net = random_network(&mut rng);
        let cfg = FusionConfig::paper_default();
        let gammas = GammaSet::synthetic(&net, case);
        let out = rcnet(&net, &gammas, &cfg, &RcnetOptions::default());
        for t in plan_network(&out.network, &out.groups, (256, 256), &chip).into_iter().flatten() {
            assert!(t.max_slab_bytes <= chip.unified_half_bytes, "case {case}");
            assert!(t.tiles >= 1 && t.tile_h >= 1, "case {case}");
        }
    }
}
