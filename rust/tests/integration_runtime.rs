//! Integration tests over the PJRT runtime + coordinator. They need the
//! `pjrt` feature (the whole file compiles away without it), which in
//! turn needs the `xla` crate added to Cargo.toml (see the feature note
//! there), plus the AOT artifacts (`make artifacts`); they self-skip
//! when artifacts are absent so the suite stays green on a fresh
//! pjrt-enabled checkout.
#![cfg(feature = "pjrt")]

use std::path::Path;

use rcnet_dla::coordinator::{run_with_runtime, PipelineConfig};
use rcnet_dla::data;
use rcnet_dla::runtime::Runtime;

const MANIFEST: &str = "artifacts/manifest.json";

fn runtime() -> Option<Runtime> {
    if !Path::new(MANIFEST).exists() {
        eprintln!("skipping: {MANIFEST} missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(MANIFEST).expect("artifacts present but unloadable"))
}

#[test]
fn groups_chain_shapes() {
    let Some(rt) = runtime() else { return };
    // Group i's output shape equals group i+1's input shape.
    for w in rt.groups.windows(2) {
        assert_eq!(w[0].meta.out_shape, w[1].meta.in_shape);
    }
    let (h, w2) = rt.manifest.input_hw;
    assert_eq!(rt.groups[0].meta.in_shape, (h, w2, 3));
}

#[test]
fn frame_executes_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let (h, w) = rt.manifest.input_hw;
    let scene = data::render(99, h, w, 4);
    let head = rt.run_frame(&scene.image).expect("frame execution");
    let (gh, gw, gc) = rt.groups.last().unwrap().meta.out_shape;
    assert_eq!(head.len(), gh * gw * gc);
    assert!(head.iter().all(|v| v.is_finite()), "non-finite head values");
    // Not all-zero (the network does *something*).
    assert!(head.iter().any(|&v| v != 0.0));
}

#[test]
fn deterministic_across_runs() {
    let Some(rt) = runtime() else { return };
    let (h, w) = rt.manifest.input_hw;
    let scene = data::render(7, h, w, 4);
    let a = rt.run_frame(&scene.image).unwrap();
    let b = rt.run_frame(&scene.image).unwrap();
    assert_eq!(a, b);
}

#[test]
fn pipeline_reports_metrics() {
    let Some(rt) = runtime() else { return };
    let cfg = PipelineConfig { frames: 3, ..Default::default() };
    let report = run_with_runtime(&rt, &cfg).expect("pipeline");
    assert_eq!(report.frames, 3);
    assert!(report.mean_latency_ms > 0.0);
    assert!(report.p99_latency_ms >= report.mean_latency_ms * 0.5);
    assert!((0.0..=1.0).contains(&report.map_50));
}

#[test]
fn pipeline_seed_changes_scenes_not_crash() {
    let Some(rt) = runtime() else { return };
    for seed in [1u64, 5000] {
        let cfg = PipelineConfig { frames: 2, seed, ..Default::default() };
        run_with_runtime(&rt, &cfg).expect("pipeline");
    }
}
