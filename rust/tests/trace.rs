//! Trace invariants across the model zoo — the contract that makes the
//! phase-level trace the single source of truth:
//!
//! 1. every builder output is structurally valid (steps tile the frame,
//!    phases stay inside their steps, engines never overlap);
//! 2. trace DRAM byte totals equal the analytic `TrafficModel` report
//!    **exactly** for every zoo model at every paper resolution, under
//!    both schedules;
//! 3. the `FrameSim` reductions and the `ExecutionEvents` energy fold
//!    agree with the trace totals bit-for-bit (the paper design point
//!    pins the old aggregate path);
//! 4. burst profiles conserve bytes and stay exactly normalized.

use rcnet_dla::config::ChipConfig;
use rcnet_dla::dla::{simulate_fused, trace_fused, trace_layer_by_layer, FrameSim};
use rcnet_dla::energy::ExecutionEvents;
use rcnet_dla::fusion::FusionConfig;
use rcnet_dla::model::zoo::{plan_fixtures, PAPER_RESOLUTIONS};
use rcnet_dla::plan::Planner;
use rcnet_dla::report::spec::{build_deployment_spec, spec_to_network, PipelineProfile};
use rcnet_dla::trace::{BurstProfile, ExecutionTrace, BURST_BUCKETS};
use rcnet_dla::traffic::TrafficModel;

fn assert_valid(trace: &ExecutionTrace, what: &str) {
    let errs = trace.validate();
    assert!(errs.is_empty(), "{what}: {errs:?}");
}

fn assert_profile_exact(trace: &ExecutionTrace, what: &str) {
    let hist = trace.dram_histogram(BURST_BUCKETS);
    assert_eq!(hist.iter().sum::<u64>(), trace.dram_bytes(), "{what}: histogram loses bytes");
    let cost = trace.frame_cost();
    assert_eq!(
        cost.profile.cumulative(BURST_BUCKETS),
        BurstProfile::SCALE,
        "{what}: profile not normalized"
    );
    assert_eq!(cost.compute_cycles, trace.total_cycles(), "{what}");
    assert_eq!(cost.dram_bytes, trace.dram_bytes(), "{what}");
}

#[test]
fn trace_bytes_match_traffic_model_across_the_zoo() {
    let chip = ChipConfig::paper_chip();
    let cfg = FusionConfig::paper_default();
    let tm = TrafficModel::new(chip);
    for fx in plan_fixtures() {
        let net = (fx.build)();
        for hw in PAPER_RESOLUTIONS {
            let what = format!("{} at {hw:?}", fx.name);

            // Layer-by-layer: every model, every resolution.
            let lbl = trace_layer_by_layer(&net, hw, &chip);
            assert_valid(&lbl, &format!("{what} (layer-by-layer)"));
            assert_eq!(
                lbl.dram_bytes(),
                tm.layer_by_layer(&net, hw).total_bytes(),
                "{what}: layer-by-layer trace bytes != traffic model"
            );
            assert_profile_exact(&lbl, &what);

            // Group-fused under the traffic-optimal plan. A tiling error
            // is acceptable only for the known physically-untileable
            // points (DeepLab's 2048-ch rows at 1080p — pinned by
            // tests/prop_planner.rs); those are skipped here.
            let plan = Planner::OptimalDp.plan(&net, &cfg, &chip, hw);
            let Ok((fused, _tilings)) = trace_fused(&net, &plan.groups, hw, &chip) else {
                continue;
            };
            assert_valid(&fused, &format!("{what} (fused)"));
            assert_eq!(
                fused.dram_bytes(),
                tm.fused(&net, &plan.groups, hw).total_bytes(),
                "{what}: fused trace bytes != traffic model"
            );
            assert_profile_exact(&fused, &what);

            // The reductions agree with the trace they fold.
            let sim = FrameSim::from_trace(&fused, &chip);
            assert_eq!(sim.total_cycles, fused.total_cycles(), "{what}");
            assert_eq!(sim.total_dram_bytes(), fused.dram_bytes(), "{what}");
            assert_eq!(sim.total_sram_bytes(), fused.sram_bytes(), "{what}");
            assert_eq!(sim.total_macs(), fused.macs(), "{what}");
        }
    }
}

#[test]
fn energy_fold_matches_old_aggregates_at_the_paper_design_point() {
    // The deployed RC-YOLOv2 at the chip's headline HD30 operating point:
    // the trace fold and the FrameSim aggregate path must produce
    // bit-identical event counts for the power model.
    let chip = ChipConfig::paper_chip();
    let spec = build_deployment_spec(PipelineProfile::Hd, 3, 5, None, 7);
    let (net, groups) = spec_to_network(&spec).expect("deployment spec");
    let (trace, _tilings) = trace_fused(&net, &groups, (720, 1280), &chip).expect("fused trace");
    let (sim, _gsims) = simulate_fused(&net, &groups, (720, 1280), &chip).expect("fused sim");

    let from_trace = ExecutionEvents::per_second(&trace, 30.0);
    let from_sim = sim.events_per_second(30.0);
    assert_eq!(from_trace.macs.to_bits(), from_sim.macs.to_bits());
    assert_eq!(from_trace.sram_bytes.to_bits(), from_sim.sram_bytes.to_bits());
    assert_eq!(from_trace.pad_bytes.to_bits(), from_sim.pad_bytes.to_bits());

    // And the per-frame fold is the plain totals.
    let per_frame = ExecutionEvents::per_frame(&trace);
    assert_eq!(per_frame.macs, trace.macs() as f64);
    assert_eq!(per_frame.pad_bytes, trace.dram_bytes() as f64);
}

#[test]
fn fused_phase_kinds_partition_the_traffic_exactly() {
    // Per-kind accounting at the HD design point, not just totals: the
    // trace's WeightDma bytes are the traffic model's weight bytes, and
    // IfmapLoad + Writeback are its feature bytes — exactly.
    use rcnet_dla::trace::PhaseKind;
    let chip = ChipConfig::paper_chip();
    let spec = build_deployment_spec(PipelineProfile::Hd, 3, 5, None, 7);
    let (net, groups) = spec_to_network(&spec).expect("deployment spec");
    let (fused, _) = trace_fused(&net, &groups, (720, 1280), &chip).expect("fused trace");
    let report = TrafficModel::new(chip).fused(&net, &groups, (720, 1280));
    let weight: u64 = fused
        .phases
        .iter()
        .filter(|p| p.kind == PhaseKind::WeightDma)
        .map(|p| p.dram_bytes)
        .sum();
    let feat: u64 = fused
        .phases
        .iter()
        .filter(|p| matches!(p.kind, PhaseKind::IfmapLoad | PhaseKind::Writeback))
        .map(|p| p.dram_bytes)
        .sum();
    assert_eq!(weight, report.weight_bytes());
    assert_eq!(feat, report.feat_bytes());
    // And the fused schedule still moves far fewer bytes than
    // layer-by-layer while the traces stay structurally valid.
    let lbl = trace_layer_by_layer(&net, (720, 1280), &chip);
    assert!(fused.dram_bytes() * 3 < lbl.dram_bytes());
}
