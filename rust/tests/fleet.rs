//! Integration tests over the fleet-serving subsystem: determinism,
//! admission control, input validation, and the headline property —
//! shrinking the shared DRAM-bus budget can only degrade service (more
//! sheds / misses).

use rcnet_dla::serve::{
    run_fleet, run_fleet_with, AdmissionPolicy, Engine, FaultEvent, FaultKind, FleetConfig,
    FleetConfigBuilder, FleetReport, QosClass, Scenario, StreamSpec,
};

fn hd15(qos: QosClass) -> StreamSpec {
    StreamSpec { hw: (720, 1280), target_fps: 15.0, qos }
}

/// A config whose scenario provides a pool of `chips` paper chips; the
/// stream list is supplied per test through [`run_fleet_with`].
fn pool(chips: usize) -> FleetConfig {
    FleetConfig::sampled(1, chips, 1)
}

fn loss(r: &FleetReport) -> f64 {
    r.loss_rate()
}

#[test]
fn halving_bus_budget_monotonically_degrades() {
    // Six HD15 streams on six chips: compute is comfortably sustainable
    // (each chip serves one stream below full utilization), so every
    // degradation as the budget halves is attributable to the bus.
    let specs = [
        hd15(QosClass::Gold),
        hd15(QosClass::Gold),
        hd15(QosClass::Silver),
        hd15(QosClass::Silver),
        hd15(QosClass::Bronze),
        hd15(QosClass::Bronze),
    ];
    let mut rates = Vec::new();
    for bus_mbps in [50_000.0, 1_000.0, 500.0, 250.0] {
        let cfg = FleetConfig {
            bus_mbps,
            seconds: 2.0,
            admission: AdmissionPolicy::AdmitAll,
            ..pool(6)
        };
        let r = run_fleet_with(&cfg, &specs).unwrap();
        assert!(r.released() > 0, "no frames released at {bus_mbps} MB/s");
        rates.push(loss(&r));
    }
    for w in rates.windows(2) {
        assert!(
            w[1] + 1e-9 >= w[0],
            "shed+miss rate must not improve when the bus shrinks: {rates:?}"
        );
    }
    assert!(rates[0] < 0.05, "uncontended bus should serve ~everything: {rates:?}");
    assert!(rates[3] > rates[0] + 0.2, "a 200x smaller bus must visibly hurt: {rates:?}");
}

#[test]
fn same_seed_same_report() {
    let cfg = FleetConfig { seconds: 1.0, ..FleetConfig::sampled(12, 4, 42) };
    let a = run_fleet(&cfg).unwrap().to_string();
    let b = run_fleet(&cfg).unwrap().to_string();
    assert_eq!(a, b, "a seeded fleet run must be reproducible");
    assert!(a.contains("bus util"));
}

#[test]
fn different_seeds_change_the_mix() {
    let a = run_fleet(&FleetConfig { seconds: 1.0, ..FleetConfig::sampled(12, 4, 1) })
        .unwrap()
        .to_string();
    let b = run_fleet(&FleetConfig { seconds: 1.0, ..FleetConfig::sampled(12, 4, 2) })
        .unwrap()
        .to_string();
    assert_ne!(a, b);
}

#[test]
fn admission_rejects_everything_on_a_starved_bus() {
    // 1 MB/s cannot carry a single HD15 stream at oversub 1.0. Every
    // scripted stream still appears in the report — as rejected.
    let specs = [hd15(QosClass::Gold); 4];
    let cfg = FleetConfig {
        bus_mbps: 1.0,
        seconds: 0.5,
        admission: AdmissionPolicy::DemandLimit { oversub: 1.0 },
        ..pool(64)
    };
    let r = run_fleet_with(&cfg, &specs).unwrap();
    assert_eq!(r.per_stream.len(), 4);
    assert_eq!(r.admitted(), 0);
    assert_eq!(r.rejected, 4);
    assert_eq!(r.released(), 0, "rejected streams release nothing");
}

#[test]
fn admission_admits_under_ample_capacity() {
    let specs = [hd15(QosClass::Silver); 4];
    let cfg = FleetConfig {
        bus_mbps: 100_000.0,
        seconds: 0.5,
        admission: AdmissionPolicy::DemandLimit { oversub: 1.0 },
        ..pool(64)
    };
    let r = run_fleet_with(&cfg, &specs).unwrap();
    assert_eq!(r.admitted(), 4);
    assert_eq!(r.rejected, 0);
}

#[test]
fn report_counts_are_consistent() {
    let cfg = FleetConfig {
        seconds: 1.0,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::sampled(8, 4, 1)
    };
    let r = run_fleet(&cfg).unwrap();
    assert_eq!(r.per_stream.len(), 8);
    assert_eq!(r.admitted(), 8);
    // Completed + shed never exceeds released (the rest is in flight at
    // the end of the simulated span).
    assert!(r.completed() + r.shed() <= r.released());
    assert!(r.missed() <= r.completed());
    assert!(r.bus_utilization >= 0.0 && r.bus_utilization <= 1.0 + 1e-9);
    assert!(r.chip_utilization >= 0.0 && r.chip_utilization <= 1.0 + 1e-9);
}

/// Satellite pin: degenerate engine knobs and scenarios must come back
/// as crate errors from `run_fleet` — not NaN reports or panics.
#[test]
fn run_fleet_validates_its_config() {
    let good = FleetConfig { seconds: 0.5, ..FleetConfig::sampled(2, 2, 1) };
    assert!(run_fleet(&good).is_ok());

    for (what, bad) in [
        ("tick_ms 0", FleetConfig { tick_ms: 0.0, ..good.clone() }),
        ("seconds 0", FleetConfig { seconds: 0.0, ..good.clone() }),
        ("bus 0", FleetConfig { bus_mbps: 0.0, ..good.clone() }),
        ("queue_depth 0", FleetConfig { queue_depth: 0, ..good.clone() }),
        ("max_ready 0", FleetConfig { max_ready_per_stream: 0, ..good.clone() }),
        (
            "oversub 0",
            FleetConfig {
                admission: AdmissionPolicy::DemandLimit { oversub: 0.0 },
                ..good.clone()
            },
        ),
        (
            "zero chips",
            FleetConfig {
                scenario: Scenario { chips: Vec::new(), ..good.scenario.clone() },
                ..good.clone()
            },
        ),
        (
            "zero streams",
            FleetConfig {
                scenario: Scenario { streams: Vec::new(), ..good.scenario.clone() },
                ..good.clone()
            },
        ),
    ] {
        assert!(run_fleet(&bad).is_err(), "{what} must be rejected");
    }

    // The same guard covers explicit stream lists with bad specs.
    let bad_spec = StreamSpec { hw: (720, 1280), target_fps: 0.0, qos: QosClass::Gold };
    assert!(run_fleet_with(&good, &[bad_spec]).is_err(), "fps 0 must be rejected");
}

/// Satellite pin: the `--engine` knob's three names round-trip through
/// `Engine::parse`/`Engine::name`, and anything else parses to `None`
/// (the CLI turns that into an error listing the valid values).
#[test]
fn engine_knob_parses_all_three_engines() {
    for (name, engine) in [
        ("tick", Engine::Tick),
        ("event", Engine::Event),
        ("event-sharded", Engine::EventSharded),
    ] {
        assert_eq!(Engine::parse(name), Some(engine));
        assert_eq!(engine.name(), name);
    }
    for bad in ["warp", "event_sharded", "sharded", "EVENT", ""] {
        assert_eq!(Engine::parse(bad), None, "{bad:?} must not parse");
    }
}

/// Satellite pin: `engine=event-sharded` with `threads=1` is a config
/// error (a single shard is just the `event` engine — the validator
/// says so instead of silently running the wrong engine), while
/// `threads=0` (auto) and explicit multi-worker counts build and run.
#[test]
fn validate_rejects_event_sharded_on_one_thread() {
    let base = FleetConfig { seconds: 0.5, ..FleetConfig::sampled(4, 2, 1) };

    let bad = FleetConfig { engine: Engine::EventSharded, threads: 1, ..base.clone() };
    assert!(bad.validate().is_err());
    let err = run_fleet(&bad).expect_err("threads=1 must be rejected");
    assert!(
        err.to_string().contains("event-sharded"),
        "the error must name the offending engine: {err}"
    );

    for threads in [0, 2, 8] {
        let cfg = FleetConfigBuilder::new(base.scenario.clone())
            .seconds(0.5)
            .engine(Engine::EventSharded)
            .threads(threads)
            .build()
            .unwrap_or_else(|e| panic!("threads={threads} must build: {e}"));
        assert_eq!(cfg.engine, Engine::EventSharded);
        run_fleet(&cfg).unwrap_or_else(|e| panic!("threads={threads} must run: {e}"));
    }
}

/// Satellite pin: malformed fault scripts come back as crate errors from
/// `run_fleet` — a fault on a chip outside the base pool, overlapping
/// same-kind intervals on one chip, a zero derate factor, and an
/// inverted interval are all rejected before the engines start.
#[test]
fn run_fleet_validates_fault_scripts() {
    let good = FleetConfig { seconds: 0.5, ..FleetConfig::sampled(2, 2, 1) };
    let with_faults = |faults: Vec<FaultEvent>| FleetConfig {
        scenario: Scenario { faults, ..good.scenario.clone() },
        ..good.clone()
    };
    let down = |chip: usize, start_ms: f64, end_ms: f64| FaultEvent {
        chip,
        start_ms,
        end_ms,
        kind: FaultKind::ChipDown,
    };

    // A well-formed script runs: adjacent (non-overlapping) same-kind
    // intervals and different kinds overlapping on one chip are legal.
    assert!(run_fleet(&with_faults(vec![down(0, 100.0, 200.0), down(0, 200.0, 300.0)]))
        .is_ok());
    assert!(run_fleet(&with_faults(vec![
        down(1, 100.0, 300.0),
        FaultEvent {
            chip: 0,
            start_ms: 150.0,
            end_ms: 250.0,
            kind: FaultKind::ThermalDerate { factor: 0.5 },
        },
    ]))
    .is_ok());

    for (what, faults) in [
        ("chip out of the base pool", vec![down(2, 100.0, 200.0)]),
        (
            "overlapping same-kind intervals on one chip",
            vec![down(0, 100.0, 300.0), down(0, 250.0, 400.0)],
        ),
        (
            "zero derate factor",
            vec![FaultEvent {
                chip: 0,
                start_ms: 100.0,
                end_ms: 200.0,
                kind: FaultKind::DramThrottle { factor: 0.0 },
            }],
        ),
        (
            "derate factor above 1",
            vec![FaultEvent {
                chip: 0,
                start_ms: 100.0,
                end_ms: 200.0,
                kind: FaultKind::ThermalDerate { factor: 1.5 },
            }],
        ),
        ("inverted interval", vec![down(0, 300.0, 100.0)]),
        ("negative start", vec![down(0, -1.0, 100.0)]),
    ] {
        assert!(run_fleet(&with_faults(faults)).is_err(), "{what} must be rejected");
    }
}
