//! Integration tests over the fleet-serving subsystem: determinism,
//! admission control, and the headline property — shrinking the shared
//! DRAM-bus budget can only degrade service (more sheds / misses).

use rcnet_dla::serve::{
    run_fleet, run_fleet_with, AdmissionPolicy, FleetConfig, FleetReport, QosClass, StreamSpec,
};

fn hd15(qos: QosClass) -> StreamSpec {
    StreamSpec { hw: (720, 1280), target_fps: 15.0, qos }
}

fn loss(r: &FleetReport) -> f64 {
    r.loss_rate()
}

#[test]
fn halving_bus_budget_monotonically_degrades() {
    // Six HD15 streams on six chips: compute is comfortably sustainable
    // (each chip serves one stream below full utilization), so every
    // degradation as the budget halves is attributable to the bus.
    let specs = [
        hd15(QosClass::Gold),
        hd15(QosClass::Gold),
        hd15(QosClass::Silver),
        hd15(QosClass::Silver),
        hd15(QosClass::Bronze),
        hd15(QosClass::Bronze),
    ];
    let mut rates = Vec::new();
    for bus_mbps in [50_000.0, 1_000.0, 500.0, 250.0] {
        let cfg = FleetConfig {
            streams: specs.len(),
            chips: 6,
            bus_mbps,
            seconds: 2.0,
            admission: AdmissionPolicy::AdmitAll,
            ..FleetConfig::default()
        };
        let r = run_fleet_with(&cfg, &specs).unwrap();
        assert!(r.released() > 0, "no frames released at {bus_mbps} MB/s");
        rates.push(loss(&r));
    }
    for w in rates.windows(2) {
        assert!(
            w[1] + 1e-9 >= w[0],
            "shed+miss rate must not improve when the bus shrinks: {rates:?}"
        );
    }
    assert!(rates[0] < 0.05, "uncontended bus should serve ~everything: {rates:?}");
    assert!(rates[3] > rates[0] + 0.2, "a 200x smaller bus must visibly hurt: {rates:?}");
}

#[test]
fn same_seed_same_report() {
    let cfg = FleetConfig {
        streams: 12,
        chips: 4,
        seconds: 1.0,
        seed: 42,
        ..FleetConfig::default()
    };
    let a = run_fleet(&cfg).unwrap().to_string();
    let b = run_fleet(&cfg).unwrap().to_string();
    assert_eq!(a, b, "a seeded fleet run must be reproducible");
    assert!(a.contains("bus util"));
}

#[test]
fn different_seeds_change_the_mix() {
    let base = FleetConfig { streams: 12, chips: 4, seconds: 1.0, ..FleetConfig::default() };
    let a = run_fleet(&FleetConfig { seed: 1, ..base }).unwrap().to_string();
    let b = run_fleet(&FleetConfig { seed: 2, ..base }).unwrap().to_string();
    assert_ne!(a, b);
}

#[test]
fn admission_rejects_everything_on_a_starved_bus() {
    // 1 MB/s cannot carry a single HD15 stream at oversub 1.0.
    let specs = [hd15(QosClass::Gold); 4];
    let cfg = FleetConfig {
        streams: specs.len(),
        chips: 64,
        bus_mbps: 1.0,
        seconds: 0.5,
        admission: AdmissionPolicy::DemandLimit { oversub: 1.0 },
        ..FleetConfig::default()
    };
    let r = run_fleet_with(&cfg, &specs).unwrap();
    assert_eq!(r.per_stream.len(), 0);
    assert_eq!(r.rejected, 4);
}

#[test]
fn admission_admits_under_ample_capacity() {
    let specs = [hd15(QosClass::Silver); 4];
    let cfg = FleetConfig {
        streams: specs.len(),
        chips: 64,
        bus_mbps: 100_000.0,
        seconds: 0.5,
        admission: AdmissionPolicy::DemandLimit { oversub: 1.0 },
        ..FleetConfig::default()
    };
    let r = run_fleet_with(&cfg, &specs).unwrap();
    assert_eq!(r.per_stream.len(), 4);
    assert_eq!(r.rejected, 0);
}

#[test]
fn report_counts_are_consistent() {
    let cfg = FleetConfig {
        streams: 8,
        chips: 4,
        seconds: 1.0,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetConfig::default()
    };
    let r = run_fleet(&cfg).unwrap();
    assert_eq!(r.per_stream.len(), 8);
    // Completed + shed never exceeds released (the rest is in flight at
    // the end of the simulated span).
    assert!(r.completed() + r.shed() <= r.released());
    assert!(r.missed() <= r.completed());
    assert!(r.bus_utilization >= 0.0 && r.bus_utilization <= 1.0 + 1e-9);
    assert!(r.chip_utilization >= 0.0 && r.chip_utilization <= 1.0 + 1e-9);
}
