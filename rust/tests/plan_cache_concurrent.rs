//! PlanCache under concurrent multi-network pricing — the regime the
//! scenario fleet's setup now runs every time a mixed-model scenario is
//! primed: several zoo models, each with a distinct
//! `Network::structural_hash`, planned from scoped threads against one
//! shared cache. Pins that entries never collide across models and that
//! every thread's plan equals the serially-computed plan for its model.

use std::sync::Arc;

use rcnet_dla::config::ChipConfig;
use rcnet_dla::fusion::FusionConfig;
use rcnet_dla::model::zoo::plan_fixtures;
use rcnet_dla::model::Network;
use rcnet_dla::plan::{Plan, PlanCache, Planner};

fn nets() -> Vec<Network> {
    // Every zoo fixture: six structurally distinct networks.
    plan_fixtures().into_iter().map(|f| (f.build)()).collect()
}

#[test]
fn concurrent_multi_network_pricing_does_not_collide() {
    let nets = nets();
    let cfg = FusionConfig::paper_default();
    let chip = ChipConfig::paper_chip();
    let hw = (416, 416);

    // Distinct structural hashes are the premise of multi-model caching.
    let mut hashes: Vec<u64> = nets.iter().map(Network::structural_hash).collect();
    hashes.sort_unstable();
    hashes.dedup();
    assert_eq!(hashes.len(), nets.len(), "zoo models must hash distinctly");

    // Reference plans, computed serially in a private cache each.
    let reference: Vec<Plan> =
        nets.iter().map(|n| Planner::OptimalDp.plan(n, &cfg, &chip, hw)).collect();

    // One shared cache, every model planned from its own thread — twice,
    // so both the cold (plan outside lock, insert) and warm (shard read)
    // paths run concurrently.
    let cache = PlanCache::new();
    for round in 0..2 {
        let plans: Vec<Arc<Plan>> = std::thread::scope(|s| {
            let handles: Vec<_> = nets
                .iter()
                .map(|n| {
                    let (cfg, chip, cache) = (&cfg, &chip, &cache);
                    s.spawn(move || cache.plan(n, cfg, chip, hw, Planner::OptimalDp))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("planning thread")).collect()
        });
        // No cross-model collisions: each thread got its own model's
        // plan, byte-equal to the serial reference.
        for ((plan, reference), net) in plans.iter().zip(&reference).zip(&nets) {
            assert_eq!(plan.groups, reference.groups, "{} round {round}", net.name);
            assert_eq!(plan.feat_bytes, reference.feat_bytes, "{} round {round}", net.name);
            // The plan tiles the *right* network: group bounds cover its
            // layer list exactly.
            let last = plan.groups.last().expect("non-empty plan");
            assert_eq!(last.end + 1, net.layers.len(), "{} round {round}", net.name);
        }
        // Exactly one entry per model, no matter how many threads raced.
        assert_eq!(cache.len(), nets.len(), "round {round}");
    }
    // Second round was all warm hits.
    assert!(cache.hits() >= nets.len() as u64);
}
