"""Pure-jnp correctness oracle for every L1 Pallas kernel.

Implemented with `jax.lax` convolution/reduction primitives — a genuinely
independent code path from the Pallas kernels (which are hand-written
shifted-slice arithmetic), so agreement is a meaningful check.

All tensors are HWC (unbatched); weights are:
  dw:   (3, 3, C)
  pw:   (C_in, C_out)
  conv: (k, k, C_in, C_out)
BN is pre-folded into (scale, shift) applied after the conv.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x, act: str):
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "leaky":
        return jnp.where(x > 0, x, 0.1 * x)
    return x


def dw3x3_ref(x, w, scale, shift, act="relu6", stride=1):
    """Depthwise 3x3, SAME padding. x: (H, W, C), w: (3, 3, C)."""
    c = x.shape[-1]
    lhs = x[None].transpose(0, 3, 1, 2)  # NCHW
    rhs = w.transpose(2, 0, 1)[:, None]  # (C, 1, 3, 3) OIHW
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=[(1, 1), (1, 1)],  # explicit: centers at 0, s, 2s, ...
        feature_group_count=c,
    )
    out = out[0].transpose(1, 2, 0)
    return _act(out * scale + shift, act)


def pw_ref(x, w, scale, shift, act="none"):
    """Pointwise 1x1. x: (H, W, C_in), w: (C_in, C_out)."""
    out = jnp.einsum("hwc,cd->hwd", x, w)
    return _act(out * scale + shift, act)


def conv3x3_ref(x, w, scale, shift, act="relu6", stride=1):
    """Dense kxk conv, SAME padding. w: (k, k, C_in, C_out)."""
    lhs = x[None].transpose(0, 3, 1, 2)
    rhs = w.transpose(3, 2, 0, 1)  # OIHW
    pad = (w.shape[0] - 1) // 2
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(stride, stride), padding=[(pad, pad), (pad, pad)]
    )
    out = out[0].transpose(1, 2, 0)
    return _act(out * scale + shift, act)


def maxpool2x2_ref(x):
    """2x2/2 max pool with ceil semantics (odd edges padded -inf)."""
    h, w, c = x.shape
    ph, pw_ = (-h) % 2, (-w) % 2
    x = jnp.pad(x, ((0, ph), (0, pw_), (0, 0)), constant_values=-jnp.inf)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (2, 2, 1), (2, 2, 1), "VALID"
    )


def residual_apply_ref(skip, conv_out):
    """Fig. 8 channel-mismatch residual add (mirrors rust
    fusion::residual::plan): add over min(c_skip, c_out); extra conv
    channels pass through; extra skip channels are dropped."""
    cs, co = skip.shape[-1], conv_out.shape[-1]
    add = min(cs, co)
    summed = conv_out[..., :add] + skip[..., :add]
    if co > add:
        return jnp.concatenate([summed, conv_out[..., add:]], axis=-1)
    return summed


def fused_block_ref(x, wd, sd, bd, wp, sp, bp, skip=None, stride=1):
    """The proposed block (Fig. 1b): dw3x3+BN+ReLU6 -> pw1x1+BN
    (+ Fig. 8 residual)."""
    mid = dw3x3_ref(x, wd, sd, bd, act="relu6", stride=stride)
    out = pw_ref(mid, wp, sp, bp, act="none")
    if skip is not None:
        out = residual_apply_ref(skip, out)
    return out
