"""L1 — Pallas kernels for the chip's compute hot-spots.

The chip executes a fusion group layer-by-layer out of its 2 x 192 KB
unified ping-pong buffer; the TPU analog (DESIGN.md §Hardware-Adaptation)
keeps a tile and its intermediate maps VMEM-resident inside one
`pallas_call`. The headline kernel, :func:`fused_block`, computes the
paper's proposed block (Fig. 1b) — depthwise 3x3 + BN + ReLU6, then
pointwise 1x1 + BN, then the Fig. 8 residual — with the depthwise
intermediate never leaving the kernel (= never leaving VMEM), exactly the
traffic-avoidance the unified buffer provides in silicon.

The pointwise stage is a `jnp.dot` so it lowers onto the MXU; the
depthwise stage is shifted-slice VPU arithmetic.

All kernels run with ``interpret=True``: the image's CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret-mode lowers to plain
HLO that the rust runtime executes (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _act_inside(x, act: str):
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "leaky":
        return jnp.where(x > 0, x, 0.1 * x)
    return x


def _dw3x3_body(xp, w, scale, shift, act, stride, out_h=None, out_w=None):
    """Shared depthwise arithmetic on an already-padded tile xp
    (H+2, W+2, C) -> (out_h, out_w, C)."""
    c = xp.shape[-1]
    h = xp.shape[0] - 2
    w_ = xp.shape[1] - 2
    acc = jnp.zeros((h, w_, c), dtype=jnp.float32)
    for i in range(3):
        for j in range(3):
            acc = acc + xp[i : i + h, j : j + w_, :] * w[i, j, :]
    if stride > 1:
        acc = acc[::stride, ::stride, :]
    return _act_inside(acc * scale + shift, act)


def dw3x3(x, w, scale, shift, act="relu6", stride=1):
    """Depthwise 3x3 (SAME) as a standalone Pallas kernel."""
    h, w_, c = x.shape
    oh, ow = -(-h // stride), -(-w_ // stride)
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))

    def kernel(x_ref, w_ref, s_ref, b_ref, o_ref):
        o_ref[...] = _dw3x3_body(
            x_ref[...], w_ref[...], s_ref[...], b_ref[...], act, stride, oh, ow
        )

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        interpret=INTERPRET,
    )(xp, w, scale, shift)


def pw(x, w, scale, shift, act="none"):
    """Pointwise 1x1 as a Pallas kernel; the matmul maps onto the MXU."""
    h, w_, c_in = x.shape
    c_out = w.shape[1]

    def kernel(x_ref, w_ref, s_ref, b_ref, o_ref):
        xm = x_ref[...].reshape(h * w_, c_in)
        out = jnp.dot(xm, w_ref[...], preferred_element_type=jnp.float32)
        out = out.reshape(h, w_, c_out) * s_ref[...] + b_ref[...]
        o_ref[...] = _act_inside(out, act)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w_, c_out), jnp.float32),
        interpret=INTERPRET,
    )(x, w, scale, shift)


def conv3x3(x, w, scale, shift, act="relu6", stride=1):
    """Dense 3x3 (SAME) — the first layer (C_in = 3). Implemented as nine
    shifted MXU matmuls accumulated in VMEM."""
    h, w_, c_in = x.shape
    c_out = w.shape[-1]
    oh, ow = -(-h // stride), -(-w_ // stride)
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))

    def kernel(x_ref, w_ref, s_ref, b_ref, o_ref):
        xpad = x_ref[...]
        acc = jnp.zeros((h * w_, c_out), dtype=jnp.float32)
        for i in range(3):
            for j in range(3):
                sl = xpad[i : i + h, j : j + w_, :].reshape(h * w_, c_in)
                acc = acc + jnp.dot(
                    sl, w_ref[i, j], preferred_element_type=jnp.float32
                )
        out = acc.reshape(h, w_, c_out)
        if stride > 1:
            out = out[::stride, ::stride, :]
        out = out * s_ref[...] + b_ref[...]
        o_ref[...] = _act_inside(out, act)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c_out), jnp.float32),
        interpret=INTERPRET,
    )(xp, w, scale, shift)


def maxpool2x2(x):
    """2x2/2 max pool (ceil semantics) in the store path, like the chip's
    pooling epilogue."""
    h, w_, c = x.shape
    ph, pw_ = (-h) % 2, (-w_) % 2
    oh, ow = (h + ph) // 2, (w_ + pw_) // 2
    xp = jnp.pad(x, ((0, ph), (0, pw_), (0, 0)), constant_values=-jnp.inf)

    def kernel(x_ref, o_ref):
        v = x_ref[...]
        o_ref[...] = jnp.maximum(
            jnp.maximum(v[0::2, 0::2, :], v[1::2, 0::2, :]),
            jnp.maximum(v[0::2, 1::2, :], v[1::2, 1::2, :]),
        )

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        interpret=INTERPRET,
    )(xp)


def _residual_inside(skip, out):
    """Fig. 8 rules, traced inside the kernel (static channel counts)."""
    cs, co = skip.shape[-1], out.shape[-1]
    add = min(cs, co)
    summed = out[..., :add] + skip[..., :add]
    if co > add:
        return jnp.concatenate([summed, out[..., add:]], axis=-1)
    return summed


def fused_block(x, wd, sd, bd, wp, sp, bp, with_skip=False, stride=1):
    """The proposed block (Fig. 1b) in ONE pallas_call: dw3x3+BN+ReLU6 ->
    pw1x1+BN (+ Fig. 8 residual with the block input). The depthwise
    intermediate lives only in kernel scope (VMEM) — the software twin of
    the unified-buffer fusion that keeps it out of DRAM on the chip."""
    h, w_, c_in = x.shape
    c_out = wp.shape[1]
    oh, ow = -(-h // stride), -(-w_ // stride)
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))

    def kernel(x_ref, wd_ref, sd_ref, bd_ref, wp_ref, sp_ref, bp_ref, o_ref):
        xpad = x_ref[...]
        mid = _dw3x3_body(
            xpad, wd_ref[...], sd_ref[...], bd_ref[...], "relu6", stride, oh, ow
        )
        out = jnp.dot(
            mid.reshape(oh * ow, c_in), wp_ref[...], preferred_element_type=jnp.float32
        ).reshape(oh, ow, c_out)
        out = out * sp_ref[...] + bp_ref[...]
        if with_skip:
            skip = xpad[1:-1, 1:-1, :]
            if stride > 1:
                skip = skip[::stride, ::stride, :]
            out = _residual_inside(skip, out)
        o_ref[...] = out

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c_out), jnp.float32),
        interpret=INTERPRET,
    )(xp, wd, sd, bd, wp, sp, bp)


@functools.partial(jax.jit, static_argnames=("act",))
def _noop(x, act="none"):  # pragma: no cover - convenience for debugging
    return _act_inside(x, act)
