"""Build-time trainer: a few hundred steps of the RC-YOLOv2 detector on
the synthetic 3-class scenes (EXPERIMENTS.md records the loss curve).

Runs the *reference* forward (pure jnp — bit-compatible with the Pallas
kernels per pytest) because interpret-mode Pallas is orders of magnitude
slower; the trained weights are then baked into the Pallas-lowered HLO by
aot.py.

Usage: python -m compile.train --spec ../artifacts/model_spec.json \
          --out ../artifacts/weights.npz --steps 200
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import detect as DET
from .model import full_forward
from .params import init_params, save_params
from .spec import load_spec

TRAIN_HW = (96, 160)  # fully-convolutional: train small, deploy larger


def yolo_loss(spec, params, img, tgt, mask):
    out = full_forward(spec, params, img, use_pallas=False)
    gh, gw = out.shape[0], out.shape[1]
    a = len(DET.ANCHORS)
    c = spec.classes
    out = out.reshape(gh, gw, a, 5 + c)
    txy = jax.nn.sigmoid(out[..., 0:2])
    twh = out[..., 2:4]  # unclipped in the loss: clip() zeroes gradients
    tobj = out[..., 4]
    tcls = out[..., 5:]

    m = mask[..., None]
    loss_xy = jnp.sum(m * (txy - tgt[..., 0:2]) ** 2)
    loss_wh = jnp.sum(m * (twh - tgt[..., 2:4]) ** 2)
    obj_bce = jnp.maximum(tobj, 0) - tobj * tgt[..., 4] + jnp.log1p(jnp.exp(-jnp.abs(tobj)))
    logp = jax.nn.log_softmax(tcls, axis=-1)
    loss_cls = -jnp.sum(m * tgt[..., 5:] * logp)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    matched = (5.0 * loss_xy + 2.0 * loss_wh + loss_cls + 5.0 * jnp.sum(mask * obj_bce)) / n
    # Strong no-object pressure: false positives dominate the anchor grid
    # (75 anchors vs ~3 objects), so the mean no-object BCE carries a 4x
    # weight — the YOLO noobj/obj balance adapted to the tiny grid.
    noobj = 4.0 * jnp.sum((1.0 - mask) * obj_bce) / (gh * gw * a)
    return matched + noobj


def make_batch(seeds, spec, hw):
    gh, gw = hw[0] // 32, hw[1] // 32
    imgs, tgts, masks = [], [], []
    for s in seeds:
        img, objs = D.render(s, hw[0], hw[1])
        tgt, mask = DET.build_targets(objs, gh, gw, spec.classes)
        imgs.append(img)
        tgts.append(tgt)
        masks.append(mask)
    return (
        jnp.array(np.stack(imgs)),
        jnp.array(np.stack(tgts)),
        jnp.array(np.stack(masks)),
    )


def train(spec_path, out_path, steps=200, batch=4, lr=1e-3, seed=0, log_path=None):
    spec = load_spec(spec_path)
    params = init_params(spec, seed=seed)
    # Trainables as a flat pytree.
    tree = {k: dict(v) for k, v in params.items()}

    def batched_loss(tree, imgs, tgts, masks):
        losses = jax.vmap(lambda i, t, m: yolo_loss(spec, tree, i, t, m))(imgs, tgts, masks)
        return jnp.mean(losses)

    grad_fn = jax.jit(jax.value_and_grad(batched_loss))

    # Hand-rolled Adam (no optax in the image).
    flat, treedef = jax.tree_util.tree_flatten(tree)
    m = [jnp.zeros_like(x) for x in flat]
    v = [jnp.zeros_like(x) for x in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8

    log = []
    t0 = time.time()
    for step in range(steps):
        seeds = [seed * 1_000_003 + step * batch + i for i in range(batch)]
        imgs, tgts, masks = make_batch(seeds, spec, TRAIN_HW)
        loss, grads = grad_fn(treedef.unflatten(flat), imgs, tgts, masks)
        gflat, _ = jax.tree_util.tree_flatten(grads)
        lr_t = lr * (1.0 + np.cos(np.pi * step / max(steps, 1))) / 2.0 + 1e-5
        new = []
        for i, (x, g) in enumerate(zip(flat, gflat)):
            m[i] = b1 * m[i] + (1 - b1) * g
            v[i] = b2 * v[i] + (1 - b2) * g * g
            mh = m[i] / (1 - b1 ** (step + 1))
            vh = v[i] / (1 - b2 ** (step + 1))
            new.append(x - lr_t * mh / (jnp.sqrt(vh) + eps))
        flat = new
        log.append(float(loss))
        if step % 10 == 0 or step == steps - 1:
            print(f"step {step:4d} loss {float(loss):8.4f} ({time.time()-t0:5.1f}s)", flush=True)

    trained = treedef.unflatten(flat)
    trained = {k: {kk: np.asarray(vv, np.float32) for kk, vv in p.items()} for k, p in trained.items()}
    save_params(trained, out_path)
    if log_path:
        Path(log_path).write_text(json.dumps({"loss": log, "steps": steps, "batch": batch}))
    print(f"saved {out_path} (final loss {log[-1]:.4f}, first {log[0]:.4f})")
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="../artifacts/model_spec.json")
    ap.add_argument("--out", default="../artifacts/weights.npz")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log", default="../artifacts/train_log.json")
    args = ap.parse_args()
    train(args.spec, args.out, steps=args.steps, batch=args.batch, lr=args.lr, log_path=args.log)


if __name__ == "__main__":
    main()
