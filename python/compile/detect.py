"""YOLO-lite detection head semantics shared by the trainer (python) and
the serving decoder (rust `detect::decode` mirrors `decode_cell`).

Head output: (gh, gw, A*(5+C)) — per anchor: [tx, ty, tw, th, to,
class logits...]. Box decode:
  cx = (col + sigmoid(tx)) / gw          bw = anchor_w * exp(clip(tw))
  cy = (row + sigmoid(ty)) / gh          bh = anchor_h * exp(clip(th))
  objectness = sigmoid(to)
"""

from __future__ import annotations

import numpy as np

# Normalized anchor sizes (w, h) — fixed, shared with rust detect::anchors.
ANCHORS = [
    (0.08, 0.10),
    (0.18, 0.20),
    (0.32, 0.32),
    (0.45, 0.28),
    (0.28, 0.45),
]


def best_anchor(w: float, h: float) -> int:
    """Anchor with the closest size (L2 in wh space) — assignment rule,
    identical in rust."""
    d = [(w - aw) ** 2 + (h - ah) ** 2 for aw, ah in ANCHORS]
    return int(np.argmin(d))


def build_targets(objs, gh: int, gw: int, classes: int) -> tuple:
    """Dense YOLO targets for one image.

    Returns (tgt (gh, gw, A, 5+C), mask (gh, gw, A)) where tgt rows are
    [tx*, ty*, log(w/aw), log(h/ah), 1, onehot...] for responsible cells.
    """
    a = len(ANCHORS)
    tgt = np.zeros((gh, gw, a, 5 + classes), np.float32)
    mask = np.zeros((gh, gw, a), np.float32)
    for o in objs:
        col = min(int(o.cx * gw), gw - 1)
        row = min(int(o.cy * gh), gh - 1)
        k = best_anchor(o.w, o.h)
        aw, ah = ANCHORS[k]
        tgt[row, col, k, 0] = o.cx * gw - col
        tgt[row, col, k, 1] = o.cy * gh - row
        tgt[row, col, k, 2] = np.log(max(o.w, 1e-3) / aw)
        tgt[row, col, k, 3] = np.log(max(o.h, 1e-3) / ah)
        tgt[row, col, k, 4] = 1.0
        tgt[row, col, k, 5 + o.cls] = 1.0
        mask[row, col, k] = 1.0
    return tgt, mask
