"""AOT lowering: per-fusion-group JAX functions (Pallas kernels inside)
-> HLO TEXT artifacts + manifest.json for the rust runtime.

HLO *text* is the interchange format, NOT `lowered.compiler_ir("hlo")
.serialize()`: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids that the rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md). Lowered with
return_tuple=True, so the rust side unwraps with `to_tuple1()`.

Weights are baked into the HLO as constants (the chip analog: the fusion
group's weights are resident in the 96 KB weight buffer for the whole
frame; the rust request path only streams feature tiles).

Usage: python -m compile.aot --spec ../artifacts/model_spec.json \
          --out-dir ../artifacts [--weights ../artifacts/weights.npz]
          [--quantize]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import group_forward
from .params import fake_quantize, init_params, load_params
from .spec import load_spec
from . import detect as DET


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as `{...}`,
    # which the rust-side text parser fills with zeros — the baked
    # weights would silently vanish. Print them in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The rust side's older HLO parser (xla_extension 0.5.1) rejects newer
    # metadata attributes (source_end_line etc.) — strip metadata.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_group(spec, group, params, use_pallas=True):
    h, w, c = group.in_shape

    def fn(x):
        return (group_forward(spec, group, params, x, use_pallas=use_pallas),)

    x_spec = jax.ShapeDtypeStruct((h, w, c), jnp.float32)
    return jax.jit(fn).lower(x_spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="../artifacts/model_spec.json")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights", default=None)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the jnp reference path instead of the Pallas kernels")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    spec = load_spec(args.spec)

    weights_path = args.weights
    if weights_path is None:
        cand = out_dir / "weights.npz"
        weights_path = str(cand) if cand.exists() else None
    if weights_path:
        params = load_params(weights_path)
        trained = True
        print(f"using trained weights from {weights_path}")
    else:
        params = init_params(spec, seed=0)
        trained = False
        print("using random-init weights (run compile.train for trained ones)")
    if args.quantize:
        params = fake_quantize(params, bits=8)

    groups_meta = []
    for g in spec.groups:
        lowered = lower_group(spec, g, params, use_pallas=not args.no_pallas)
        text = to_hlo_text(lowered)
        fname = f"group_{g.id:02d}.hlo.txt"
        (out_dir / fname).write_text(text)
        print(f"  {fname}: in {g.in_shape} out {g.out_shape} ({len(text)} chars)")
        groups_meta.append(
            {
                "id": g.id,
                "file": fname,
                "in_shape": list(g.in_shape),
                "out_shape": list(g.out_shape),
                "tiles": g.tiles,
                "tile_h": g.tile_h,
            }
        )

    manifest = {
        "name": spec.name,
        "input_hw": list(spec.input_hw),
        "classes": spec.classes,
        "anchors": DET.ANCHORS,
        "groups": groups_meta,
        "trained": trained,
        "quantized": bool(args.quantize),
        "pallas": not args.no_pallas,
        "spec": str(Path(args.spec).name),
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'} ({len(groups_meta)} groups)")


if __name__ == "__main__":
    main()
