"""Model-spec loader.

The rust fusion engine (L3) is the source of truth for the network
structure: `rcnet-dla emit-spec` runs the full RCNet pipeline (conversion,
group partition, gamma pruning, tile planning) and writes
``artifacts/model_spec.json``. This module loads that spec into light
dataclasses consumed by the L2 model builder and the AOT lowerer.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional


@dataclass
class LayerSpec:
    name: str
    kind: str  # conv | dw | pw | maxpool | gap | dense | reorg | concat | upsample
    k: int
    s: int
    d: int
    c_in: int
    c_out: int
    bn: bool
    act: str  # none | relu6 | leaky | relu
    branch_from: Optional[int]


@dataclass
class SpanSpec:
    kind: str  # residual | concat
    start: int
    end: int


@dataclass
class GroupSpec:
    id: int
    start: int
    end: int
    tile_h: Optional[int]
    tiles: Optional[int]
    in_shape: tuple  # (h, w, c)
    out_shape: tuple


@dataclass
class ModelSpec:
    name: str
    input_hw: tuple
    c_in: int
    classes: int
    anchors: int
    layers: list = field(default_factory=list)
    spans: list = field(default_factory=list)
    groups: list = field(default_factory=list)

    def residual_span_ending_at(self, i: int) -> Optional[SpanSpec]:
        for sp in self.spans:
            if sp.kind == "residual" and sp.end == i:
                return sp
        return None

    def group_layers(self, g: GroupSpec) -> list:
        return self.layers[g.start : g.end + 1]


def load_spec(path) -> ModelSpec:
    raw = json.loads(Path(path).read_text())
    layers = [
        LayerSpec(
            name=l["name"],
            kind=l["kind"],
            k=int(l["k"]),
            s=int(l["s"]),
            d=int(l["d"]),
            c_in=int(l["c_in"]),
            c_out=int(l["c_out"]),
            bn=bool(l["bn"]),
            act=l["act"],
            branch_from=l["branch_from"],
        )
        for l in raw["layers"]
    ]
    spans = [SpanSpec(sp["kind"], int(sp["start"]), int(sp["end"])) for sp in raw["spans"]]
    groups = [
        GroupSpec(
            id=int(g["id"]),
            start=int(g["start"]),
            end=int(g["end"]),
            tile_h=None if g["tile_h"] is None else int(g["tile_h"]),
            tiles=None if g["tiles"] is None else int(g["tiles"]),
            in_shape=tuple(int(x) for x in g["in_shape"]),
            out_shape=tuple(int(x) for x in g["out_shape"]),
        )
        for g in raw["groups"]
    ]
    return ModelSpec(
        name=raw["name"],
        input_hw=tuple(int(x) for x in raw["input_hw"]),
        c_in=int(raw["c_in"]),
        classes=int(raw["classes"]),
        anchors=int(raw["anchors"]),
        layers=layers,
        spans=spans,
        groups=groups,
    )
