"""Synthetic HD traffic-scene generator — the IVS_3cls stand-in.

The paper's HD dataset (IVS_3cls: road-traffic objects in 3 classes) is
not public; this generator renders deterministic scenes of 3 geometric
object classes on textured backgrounds. The *same* generator exists in
rust (`rust/src/data/synthetic.rs`), driven by the same SplitMix64 stream
in the same draw order, so the build-time trainer (python) and the
serving/eval pipeline (rust) see identical scenes for a given seed —
pytest and cargo test both pin golden values.

Classes: 0 = box (car-like), 1 = disc (sign-like), 2 = wedge
(pedestrian-like). Images are float32 HWC in [0, 1].
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Bit-exact mirror of rust `util::rng::Rng`."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def f64(self) -> float:
        return (self.next_u64() >> 11) / float(1 << 53)

    def range(self, lo: int, hi: int) -> int:
        return lo + self.next_u64() % (hi - lo)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + self.f64() * (hi - lo)


@dataclass
class SceneObject:
    cls: int
    cx: float  # normalized center x
    cy: float
    w: float  # normalized width
    h: float
    shade: float


def scene_objects(seed: int, max_objects: int = 6) -> list:
    """Draw the scene parameter list — MUST stay in lockstep with
    rust `data::synthetic::scene_objects`."""
    rng = SplitMix64(seed)
    n = 1 + rng.range(0, max_objects)
    objs = []
    for _ in range(n):
        cls = rng.range(0, 3)
        cx = rng.uniform(0.1, 0.9)
        cy = rng.uniform(0.15, 0.85)
        w = rng.uniform(0.06, 0.28)
        h = rng.uniform(0.06, 0.28)
        shade = rng.uniform(0.45, 1.0)
        objs.append(SceneObject(int(cls), cx, cy, w, h, shade))
    return objs


def render(seed: int, h: int, w: int, max_objects: int = 6) -> tuple:
    """Render (image (h, w, 3) float32, objects). Integer-arithmetic
    texture so rust reproduces pixels exactly."""
    objs = scene_objects(seed, max_objects)
    ys, xs = np.mgrid[0:h, 0:w]
    tex = ((xs * 7 + ys * 13) % 32).astype(np.float32) / 255.0
    base = 0.25 + 0.5 * ((seed >> 8) % 64) / 64.0
    img = np.stack([tex + base * 0.5, tex + base * 0.4, tex + base * 0.3], axis=-1)
    for o in objs:
        x0 = int((o.cx - o.w / 2) * w)
        x1 = int((o.cx + o.w / 2) * w)
        y0 = int((o.cy - o.h / 2) * h)
        y1 = int((o.cy + o.h / 2) * h)
        x0, x1 = max(x0, 0), min(x1, w - 1)
        y0, y1 = max(y0, 0), min(y1, h - 1)
        if x1 <= x0 or y1 <= y0:
            continue
        yy, xx = np.mgrid[y0 : y1 + 1, x0 : x1 + 1]
        if o.cls == 0:  # box
            mask = np.ones_like(yy, dtype=bool)
        elif o.cls == 1:  # disc
            cx_px, cy_px = (x0 + x1) / 2.0, (y0 + y1) / 2.0
            rx, ry = max((x1 - x0) / 2.0, 1.0), max((y1 - y0) / 2.0, 1.0)
            mask = ((xx - cx_px) / rx) ** 2 + ((yy - cy_px) / ry) ** 2 <= 1.0
        else:  # wedge
            fy = (yy - y0) / max(y1 - y0, 1)
            cx_px = (x0 + x1) / 2.0
            half = (x1 - x0) / 2.0
            mask = np.abs(xx - cx_px) <= fy * half
        # Class-coded dominant channel.
        color = np.zeros(3, np.float32)
        color[o.cls] = o.shade
        color[(o.cls + 1) % 3] = o.shade * 0.25
        region = img[y0 : y1 + 1, x0 : x1 + 1, :]
        region[mask] = color
    return np.clip(img, 0.0, 1.0).astype(np.float32), objs
