"""Parameter store: init, save/load, fake-quantization.

Parameters are keyed by layer name. BN is kept pre-folded as (scale,
shift) — the chip folds BN into the convolution epilogue the same way
(§IV-C: "the processing of BN and ReLU6" happens in the accumulator's
output path).
"""

from __future__ import annotations

import hashlib

import numpy as np


def _seed_for(name: str, seed: int) -> int:
    h = hashlib.sha256(f"{seed}:{name}".encode()).digest()
    return int.from_bytes(h[:8], "little")


def init_layer(layer, seed: int) -> dict:
    """He-normal init for a LayerSpec; returns {} for weightless layers."""
    rng = np.random.default_rng(_seed_for(layer.name, seed))
    c_in, c_out, k = layer.c_in, layer.c_out, layer.k
    if layer.kind == "conv":
        fan = k * k * c_in
        w = rng.normal(0, np.sqrt(2.0 / fan), size=(k, k, c_in, c_out))
    elif layer.kind == "dw":
        fan = k * k
        w = rng.normal(0, np.sqrt(2.0 / fan), size=(k, k, c_in))
    elif layer.kind in ("pw", "dense"):
        fan = c_in
        w = rng.normal(0, np.sqrt(2.0 / fan), size=(c_in, c_out))
    else:
        return {}
    return {
        "w": w.astype(np.float32),
        "scale": np.ones(c_out, np.float32),
        "shift": np.zeros(c_out, np.float32),
    }


def init_params(spec, seed: int = 0) -> dict:
    return {l.name: init_layer(l, seed) for l in spec.layers if l.kind in ("conv", "dw", "pw", "dense")}


def save_params(params: dict, path) -> None:
    flat = {}
    for name, p in params.items():
        for k, v in p.items():
            flat[f"{name}/{k}"] = v
    np.savez(path, **flat)


def load_params(path) -> dict:
    flat = np.load(path)
    out: dict = {}
    for key in flat.files:
        name, k = key.rsplit("/", 1)
        out.setdefault(name, {})[k] = flat[key]
    return out


def fake_quantize(params: dict, bits: int = 8) -> dict:
    """Symmetric per-tensor weight quantization (Table I-III's last
    column): quantize to `bits` and dequantize, so the lowered HLO carries
    int8-representable weights."""
    qmax = float(2 ** (bits - 1) - 1)
    out = {}
    for name, p in params.items():
        q = dict(p)
        w = p["w"]
        scale = max(float(np.max(np.abs(w))), 1e-8) / qmax
        q["w"] = (np.round(w / scale).clip(-qmax, qmax) * scale).astype(np.float32)
        out[name] = q
    return out
