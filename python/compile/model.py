"""L2 — the RC-YOLOv2 forward graph in JAX, built from the rust-emitted
model spec and calling the L1 Pallas kernels.

`group_forward` executes one fusion group — the unit the rust coordinator
executes per PJRT call. Adjacent dw+pw pairs (the paper's proposed block,
Fig. 1b) collapse into the single `fused_block` Pallas kernel so the
depthwise intermediate stays VMEM-resident, mirroring the chip's unified
buffer. `full_forward` chains all groups (used for training and as the
integration oracle).

Set ``use_pallas=False`` to run the pure-jnp reference implementations —
mathematically identical (pytest asserts it), and much faster for the
build-time training loop.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import fused_block as K
from .kernels import ref as R


def _is_block_pair(spec, layers, gstart, idx):
    """dw at idx followed by pw, both inside the group slice."""
    if idx + 1 >= len(layers):
        return False
    a, b = layers[idx], layers[idx + 1]
    if a.kind != "dw" or b.kind != "pw" or b.s != 1:
        return False
    # A residual span must cover exactly this pair (or not touch it).
    gi = gstart + idx
    for sp in spec.spans:
        if sp.kind != "residual":
            continue
        covers_a = sp.start <= gi <= sp.end
        covers_b = sp.start <= gi + 1 <= sp.end
        if covers_a != covers_b:
            return False
        if covers_a and (sp.start != gi or sp.end != gi + 1):
            return False
    return True


def _pair_has_skip(spec, gi):
    return any(
        sp.kind == "residual" and sp.start == gi and sp.end == gi + 1
        for sp in spec.spans
    )


def group_forward(spec, group, params, x, use_pallas=True):
    """Run fusion group `group` on input tile `x` (H, W, C_in)."""
    layers = spec.group_layers(group)
    i = 0
    while i < len(layers):
        l = layers[i]
        gi = group.start + i
        p = params.get(l.name)
        if l.kind == "dw" and _is_block_pair(spec, layers, group.start, i):
            nxt = layers[i + 1]
            pn = params[nxt.name]
            skip = _pair_has_skip(spec, gi)
            if use_pallas:
                x = K.fused_block(
                    x, p["w"], p["scale"], p["shift"],
                    pn["w"], pn["scale"], pn["shift"],
                    with_skip=skip, stride=l.s,
                )
            else:
                x = R.fused_block_ref(
                    x, p["w"], p["scale"], p["shift"],
                    pn["w"], pn["scale"], pn["shift"],
                    skip=x if skip else None, stride=l.s,
                )
            i += 2
            continue
        if l.kind == "dw":
            f = K.dw3x3 if use_pallas else R.dw3x3_ref
            x = f(x, p["w"], p["scale"], p["shift"], act=l.act, stride=l.s)
        elif l.kind == "pw":
            f = K.pw if use_pallas else R.pw_ref
            x = f(x, p["w"], p["scale"], p["shift"], act=l.act)
        elif l.kind == "conv":
            if l.k == 1:
                f = K.pw if use_pallas else R.pw_ref
                w = p["w"][0, 0] if p["w"].ndim == 4 else p["w"]
                x = f(x, w, p["scale"], p["shift"], act=l.act)
            else:
                f = K.conv3x3 if use_pallas else R.conv3x3_ref
                x = f(x, p["w"], p["scale"], p["shift"], act=l.act, stride=l.s)
        elif l.kind == "maxpool":
            f = K.maxpool2x2 if use_pallas else R.maxpool2x2_ref
            x = f(x)
        elif l.kind == "dense":
            x = R.pw_ref(x, p["w"], p["scale"], p["shift"], act=l.act)
        elif l.kind == "gap":
            x = jnp.mean(x, axis=(0, 1), keepdims=True)
        else:
            raise NotImplementedError(f"layer kind {l.kind} in lowered path")
        i += 1
    return x


def full_forward(spec, params, x, use_pallas=False):
    """All groups back-to-back. Training uses the ref path
    (use_pallas=False) for speed; pytest asserts both paths agree."""
    for g in spec.groups:
        x = group_forward(spec, g, params, x, use_pallas=use_pallas)
    return x
