"""Gamma trainer — §II-C eq. (6)/(7): pruning-from-scratch [30].

All convolution weights are FROZEN at random init; only the per-channel
BN scale factors gamma are trained, with an L1 penalty weighted by each
layer's weight size S_l (eq. 4's size-aware regularization). The trained
gammas land in ``artifacts/gammas.json``; `rcnet-dla emit-spec --gammas`
then uses them instead of the synthetic saliency proxy, closing the loop
of Algorithm 1 across the rust/python boundary.

Usage: python -m compile.rcnet --spec ../artifacts/model_spec.json \
          --out ../artifacts/gammas.json --steps 60
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import detect as DET
from .model import full_forward
from .params import init_params
from .spec import load_spec
from .train import TRAIN_HW, make_batch, yolo_loss


def train_gammas(spec_path, out_path, steps=60, batch=2, lr=5e-2, lam=1e-4, seed=0):
    spec = load_spec(spec_path)
    frozen = init_params(spec, seed=seed)
    names = [l.name for l in spec.layers if l.kind in ("conv", "dw", "pw") and l.bn]
    sizes = {
        l.name: float(l.k * l.k * l.c_in * (1 if l.kind == "dw" else l.c_out))
        for l in spec.layers
        if l.name in set(names)
    }
    mean_size = np.mean(list(sizes.values()))
    gammas = {n: jnp.ones(frozen[n]["scale"].shape, jnp.float32) for n in names}

    def with_gammas(g):
        p = {k: dict(v) for k, v in frozen.items()}
        for n in names:
            p[n]["scale"] = g[n]
        return p

    def loss_fn(g, imgs, tgts, masks):
        p = with_gammas(g)
        task = jnp.mean(
            jax.vmap(lambda i, t, m: yolo_loss(spec, p, i, t, m))(imgs, tgts, masks)
        )
        # eq. (4): L1 on gamma, weighted by the layer's weight size so
        # pruning pressure tracks bytes freed, not just channel count.
        reg = sum(
            (sizes[n] / mean_size) * jnp.sum(jnp.abs(g[n])) for n in names
        )
        return task + lam * reg

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for step in range(steps):
        seeds = [seed * 7_654_321 + step * batch + i for i in range(batch)]
        imgs, tgts, masks = make_batch(seeds, spec, TRAIN_HW)
        loss, grads = grad_fn(gammas, imgs, tgts, masks)
        gammas = {n: gammas[n] - lr * grads[n] for n in names}
        if step % 10 == 0 or step == steps - 1:
            print(f"gamma step {step:3d} loss {float(loss):8.4f}", flush=True)

    out = {
        "gammas": [
            {"layer": n, "values": [float(abs(x)) for x in np.asarray(gammas[n])]}
            for n in names
        ]
    }
    with open(out_path, "w") as f:
        json.dump(out, f)
    print(f"saved {out_path} ({len(names)} layers)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="../artifacts/model_spec.json")
    ap.add_argument("--out", default="../artifacts/gammas.json")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1e-4)
    args = ap.parse_args()
    train_gammas(args.spec, args.out, steps=args.steps, batch=args.batch, lam=args.lam)


if __name__ == "__main__":
    main()
