"""Cross-language parity of the synthetic dataset generator."""

import numpy as np

from compile.data import SplitMix64, render, scene_objects


def test_splitmix_golden():
    # Same golden values as rust util::rng::tests::splitmix_golden.
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_scene_objects_deterministic():
    a = scene_objects(42)
    b = scene_objects(42)
    assert a == b
    assert scene_objects(43) != a


def test_scene_objects_bounds():
    for seed in range(50):
        for o in scene_objects(seed):
            assert 0 <= o.cls < 3
            assert 0.1 <= o.cx <= 0.9
            assert 0.15 <= o.cy <= 0.85
            assert 0.06 <= o.w <= 0.28
            assert 0.45 <= o.shade <= 1.0


def test_render_shape_and_range():
    img, objs = render(7, 48, 64)
    assert img.shape == (48, 64, 3)
    assert img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0
    assert len(objs) >= 1


def test_render_objects_visible():
    # An object's dominant channel should exceed background at its center.
    img, objs = render(11, 96, 128)
    o = objs[0]
    y, x = int(o.cy * 96), int(o.cx * 128)
    if o.cls == 0:  # box: center always inside
        assert img[y, x, o.cls] > 0.4
