"""AOT lowering smoke: one group lowers to non-trivial HLO text, and the
manifest round-trips through the rust-side conventions."""

import json
from pathlib import Path

import pytest

from compile.aot import lower_group, to_hlo_text
from compile.params import init_params
from compile.spec import load_spec

ART = Path(__file__).resolve().parents[2] / "artifacts"
SPEC = ART / "model_spec.json"

needs_spec = pytest.mark.skipif(not SPEC.exists(), reason="run `make spec` first")


@needs_spec
def test_lower_smallest_group_to_hlo_text():
    spec = load_spec(SPEC)
    params = init_params(spec, seed=0)
    g = min(spec.groups, key=lambda g: g.in_shape[0] * g.in_shape[1] * g.in_shape[2])
    lowered = lower_group(spec, g, params, use_pallas=True)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # No Mosaic custom-calls (interpret mode lowers to plain HLO).
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


@needs_spec
def test_manifest_consistency_when_built():
    mpath = ART / "manifest.json"
    if not mpath.exists():
        pytest.skip("run `make artifacts` first")
    m = json.loads(mpath.read_text())
    spec = load_spec(SPEC)
    assert m["classes"] == spec.classes
    assert len(m["groups"]) == len(spec.groups)
    for gm, gs in zip(m["groups"], spec.groups):
        assert tuple(gm["in_shape"]) == gs.in_shape
        assert tuple(gm["out_shape"]) == gs.out_shape
        assert (ART / gm["file"]).exists()
