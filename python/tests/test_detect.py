"""Detection target construction properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.data import SceneObject
from compile.detect import ANCHORS, best_anchor, build_targets


def test_best_anchor_identity():
    for i, (w, h) in enumerate(ANCHORS):
        assert best_anchor(w, h) == i


@settings(max_examples=50, deadline=None)
@given(
    st.floats(min_value=0.1, max_value=0.9),
    st.floats(min_value=0.15, max_value=0.85),
    st.floats(min_value=0.06, max_value=0.28),
    st.floats(min_value=0.06, max_value=0.28),
    st.integers(min_value=0, max_value=2),
)
def test_build_targets_places_object(cx, cy, w, h, cls):
    o = SceneObject(cls, cx, cy, w, h, 0.9)
    tgt, mask = build_targets([o], 6, 10, 3)
    assert mask.sum() == 1.0
    row, col = int(cy * 6), int(cx * 10)
    row, col = min(row, 5), min(col, 9)
    k = best_anchor(w, h)
    assert mask[row, col, k] == 1.0
    assert tgt[row, col, k, 4] == 1.0
    assert tgt[row, col, k, 5 + cls] == 1.0
    # Offsets inside the cell.
    assert 0.0 <= tgt[row, col, k, 0] <= 1.0
    assert 0.0 <= tgt[row, col, k, 1] <= 1.0


def test_collision_keeps_single_assignment():
    a = SceneObject(0, 0.5, 0.5, 0.1, 0.1, 0.9)
    b = SceneObject(1, 0.5, 0.5, 0.1, 0.1, 0.9)  # same cell, same anchor
    tgt, mask = build_targets([a, b], 6, 10, 3)
    assert mask.sum() == 1.0  # later object overwrites
    assert tgt[..., 5 + 1].sum() == 1.0
