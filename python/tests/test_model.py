"""L2 integration: per-group Pallas path vs ref path, full-model shape,
spec loading, training loss step sanity."""

from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import full_forward, group_forward
from compile.params import init_params
from compile.spec import load_spec

SPEC = Path(__file__).resolve().parents[2] / "artifacts" / "model_spec.json"

needs_spec = pytest.mark.skipif(not SPEC.exists(), reason="run `make spec` first")


@pytest.fixture(scope="module")
def spec():
    return load_spec(SPEC)


@pytest.fixture(scope="module")
def params(spec):
    return init_params(spec, seed=0)


@needs_spec
def test_spec_loads(spec):
    assert spec.classes == 3
    assert len(spec.groups) >= 5
    assert spec.groups[0].start == 0
    assert spec.groups[-1].end == len(spec.layers) - 1


@needs_spec
def test_group_shapes_chain(spec):
    for a, b in zip(spec.groups, spec.groups[1:]):
        assert a.out_shape == b.in_shape


@needs_spec
def test_pallas_and_ref_paths_agree_per_group(spec, params):
    rng = np.random.default_rng(1)
    # Every group, small spatial slice of its declared input channels.
    for g in spec.groups:
        _, _, c = g.in_shape
        # Use a reduced spatial size (stride structure preserved: the
        # group's pool factor divides 32).
        x = jnp.array(rng.normal(size=(32, 32, c)), dtype=jnp.float32)
        got = group_forward(spec, g, params, x, use_pallas=True)
        want = group_forward(spec, g, params, x, use_pallas=False)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@needs_spec
def test_full_forward_output_shape(spec, params):
    rng = np.random.default_rng(2)
    x = jnp.array(rng.normal(size=(64, 96, 3)), dtype=jnp.float32)
    out = full_forward(spec, params, x, use_pallas=False)
    assert out.shape == (2, 3, 5 * (5 + spec.classes))
    assert bool(jnp.all(jnp.isfinite(out)))


@needs_spec
def test_training_step_decreases_on_fixed_batch(spec, params):
    # One fixed batch, a few gradient steps: loss must strictly decrease
    # (the full trainer uses fresh scenes per step, so its curve is noisy;
    # this isolates the optimization correctness).
    import jax

    from compile.train import make_batch, yolo_loss

    imgs, tgts, masks = make_batch([11, 12], spec, (64, 96))

    def loss_fn(p):
        return jnp.mean(
            jax.vmap(lambda i, t, m: yolo_loss(spec, p, i, t, m))(imgs, tgts, masks)
        )

    g = jax.jit(jax.value_and_grad(loss_fn))
    p = {k: dict(v) for k, v in params.items()}
    l0, grads = g(p)
    # Plain SGD needs a small step: the initial wh gradients are large
    # (raw-logit regression), 1e-3 diverges.
    for _ in range(8):
        p = jax.tree_util.tree_map(lambda x, d: x - 1e-5 * d, p, grads)
        l1, grads = g(p)
    assert float(l1) < float(l0), (float(l0), float(l1))
