"""L1 correctness: Pallas kernels vs the pure-jnp oracle — the core
numeric signal of the build path. Hypothesis sweeps shapes/strides."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_block as K
from compile.kernels import ref as R

RNG = np.random.default_rng(0)


def arr(*shape):
    return jnp.array(RNG.normal(size=shape), dtype=jnp.float32)


shapes = st.tuples(
    st.integers(min_value=3, max_value=14),  # h
    st.integers(min_value=3, max_value=14),  # w
    st.integers(min_value=1, max_value=12),  # c
)


@settings(max_examples=25, deadline=None)
@given(shapes, st.sampled_from([1, 2]), st.sampled_from(["relu6", "none", "leaky"]))
def test_dw3x3_matches_ref(shape, stride, act):
    h, w, c = shape
    x, wd = arr(h, w, c), arr(3, 3, c)
    s, b = arr(c), arr(c)
    got = K.dw3x3(x, wd, s, b, act=act, stride=stride)
    want = R.dw3x3_ref(x, wd, s, b, act=act, stride=stride)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(min_value=1, max_value=16))
def test_pw_matches_ref(shape, c_out):
    h, w, c = shape
    x, wp = arr(h, w, c), arr(c, c_out)
    s, b = arr(c_out), arr(c_out)
    got = K.pw(x, wp, s, b, act="relu6")
    want = R.pw_ref(x, wp, s, b, act="relu6")
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(shapes, st.integers(min_value=1, max_value=10), st.sampled_from([1, 2]))
def test_fused_block_matches_ref(shape, c_out, stride):
    h, w, c = shape
    x, wd, wp = arr(h, w, c), arr(3, 3, c), arr(c, c_out)
    sd, bd, sp, bp = arr(c), arr(c), arr(c_out), arr(c_out)
    got = K.fused_block(x, wd, sd, bd, wp, sp, bp, stride=stride)
    want = R.fused_block_ref(x, wd, sd, bd, wp, sp, bp, stride=stride)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=10), st.integers(min_value=1, max_value=10))
def test_fused_block_residual_fig8(c_in, c_out):
    # Fig. 8 channel-mismatch rules, both directions.
    x, wd, wp = arr(8, 8, c_in), arr(3, 3, c_in), arr(c_in, c_out)
    sd, bd, sp, bp = arr(c_in), arr(c_in), arr(c_out), arr(c_out)
    got = K.fused_block(x, wd, sd, bd, wp, sp, bp, with_skip=True)
    want = R.fused_block_ref(x, wd, sd, bd, wp, sp, bp, skip=x)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(shapes)
def test_maxpool_matches_ref(shape):
    h, w, c = shape
    x = arr(h, w, c)
    got = K.maxpool2x2(x)
    want = R.maxpool2x2_ref(x)
    assert got.shape == ((h + 1) // 2, (w + 1) // 2, c)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),
    st.integers(min_value=4, max_value=12),
    st.integers(min_value=1, max_value=8),
    st.sampled_from([1, 2]),
)
def test_conv3x3_matches_ref(h, w, c_out, stride):
    x, wc = arr(h, w, 3), arr(3, 3, 3, c_out)
    s, b = arr(c_out), arr(c_out)
    got = K.conv3x3(x, wc, s, b, stride=stride)
    want = R.conv3x3_ref(x, wc, s, b, stride=stride)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_residual_apply_matches_rust_plan():
    # Golden cases shared with rust fusion::residual tests.
    skip = jnp.ones((2, 2, 3))
    conv = jnp.full((2, 2, 2), 10.0)
    out = R.residual_apply_ref(skip, conv)  # drop 1 skip channel
    assert out.shape == (2, 2, 2)
    assert float(out[0, 0, 0]) == 11.0
    out = R.residual_apply_ref(conv, skip * 3)  # 1 passthrough channel
    assert out.shape == (2, 2, 3)
    assert float(out[0, 0, 2]) == 3.0


def test_relu6_saturates():
    x = jnp.array([[[-1.0, 3.0, 9.0]]])
    w = jnp.zeros((3, 3, 3)).at[1, 1].set(1.0)
    out = K.dw3x3(x, w, jnp.ones(3), jnp.zeros(3), act="relu6")
    np.testing.assert_allclose(out[0, 0], [0.0, 3.0, 6.0])


@pytest.mark.parametrize("bits,max_err", [(8, 0.02), (4, 0.3)])
def test_fake_quantize_error_bounded(bits, max_err):
    from compile.params import fake_quantize

    p = {"l": {"w": RNG.normal(size=(64, 64)).astype(np.float32),
               "scale": np.ones(64, np.float32),
               "shift": np.zeros(64, np.float32)}}
    q = fake_quantize(p, bits=bits)
    err = np.abs(q["l"]["w"] - p["l"]["w"]).max()
    assert err <= max_err, err
